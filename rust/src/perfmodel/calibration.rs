//! Measured-kernel calibration for the analytical performance model.
//!
//! The paper's speedups (eq. 8/9, tab. 3/4/6) are *modelled*: they assume
//! hardware whose multiply cost scales with the word length WL and whose
//! sparse layers skip zero weights for free. The native backend now has
//! measured kernels — `benches/native.rs` times the dense blocked GEMM and
//! the sparse inference kernel across sparsity levels and records the rates
//! in `BENCH_native.json` — so the model's predictions can be sanity-checked
//! against what the CPU kernels actually deliver.
//!
//! The two deliberately differ: a CPU multiplies f32 at one speed whatever
//! WL says, so the *measured* inference speedup comes from sparsity alone,
//! while the *modelled* one (`perfmodel::inference_speedup`) also credits
//! the WL reduction an ASIC would exploit. Comparing the two quantifies how
//! much of the paper's claimed speedup needs bespoke hardware and how much
//! the zeros already buy on stock CPUs.
//!
//! `BENCH_native.json` carries the rates as `derived` entries (written by
//! `benches/native.rs`):
//!
//! * `calibration_dense_madds_per_ms` — dense rate, measured as the
//!   density-1.0 row of the same fused infer-layer sweep as the sparse
//!   rates;
//! * `calibration_sparse_madds_per_ms_d<DD>` — sparse kernel rate at
//!   density `DD`% (e.g. `_d30` is a 0.30 non-zero fraction);
//! * `calibration_int_madds_per_ms_wl<WL>` — integer-GEMM rate with
//!   panels stored at width `WL` (the i8/i16 paths; optional — dumps from
//!   before the integer path carry none, and the model then charges every
//!   dense layer the f32 rate);
//! * `calibration_conv_madds_per_ms` — conv-layer rate measured through
//!   the full im2col + packed-GEMM lowering on the LeNet-shape grid
//!   (optional; per-shape `calibration_conv_madds_per_ms_<shape>` rows
//!   ride along for inspection but only the aggregate is consumed).
//!   Conv MAdds are the eq. 8/9 `oh·ow·kh·kw·ci·co` counts the manifests
//!   carry, so the rate folds in the column-gather overhead — that is
//!   exactly the gap between it and the dense rate;
//! * `sparse_crossover_density` — highest measured density where the
//!   sparse kernel still beats the dense one.
//!
//! With the integer keys present the measured model stops assuming "a CPU
//! multiplies f32 at one speed whatever WL says": layers whose final word
//! length fits i8/i16 storage are charged the measured integer rate, the
//! same dispatch `runtime::native::ModelSnapshot` applies at pack time.
//!
//! Since the serving subsystem exists, a second measured source sits next
//! to the kernel rates: `benches/serve.rs` drives the full
//! registry→queue→worker pipeline and records end-to-end serving
//! throughput per `(max_batch, workers)` cell into `BENCH_serve.json`
//! (`serve_samples_per_ms_b<B>_w<W>` derived entries, plus the
//! cached-vs-rebuilt pack ablation `serve_pack_cache_speedup`).
//! [`ServeCalibration`] parses those — or folds a live
//! [`ServeStatsSnapshot`](crate::serve::ServeStatsSnapshot) via
//! [`ServeRate::from_snapshot`] — so the serving stack's delivered rate can
//! be compared against the raw kernel rate it schedules
//! ([`ServeCalibration::kernel_fraction`]): the gap is pure
//! batching/queueing/scatter overhead, which no WL or sparsity model
//! accounts for.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::sp_rows;
use crate::metrics::RunRecord;
use crate::runtime::manifest::LayerDesc;
use crate::util::json::Json;

/// Measured native-kernel throughput, parsed from `BENCH_native.json`.
#[derive(Debug, Clone)]
pub struct KernelCalibration {
    /// Dense rate in MAdds per millisecond — the density-1.0 row of the
    /// SAME fused infer-layer sweep the sparse rates come from, so the two
    /// sides (and the crossover derived from them) are mutually consistent.
    pub dense_madds_per_ms: f64,
    /// `(density, MAdds/ms)` rows for the sparse inference kernel,
    /// density-ascending. The MAdd count is the DENSE madds of the layer —
    /// the rate already folds in the skipped zeros, which is what makes
    /// sparse rates exceed the dense rate at low density.
    pub sparse_rates: Vec<(f64, f64)>,
    /// Highest measured density at which the sparse kernel still beat the
    /// dense one (the bench's recommendation for `ADAPT_SPARSE_CROSSOVER`).
    pub crossover_density: f64,
    /// `(storage WL, MAdds/ms)` rows for the integer GEMM path,
    /// width-ascending (`calibration_int_madds_per_ms_wl<WL>` entries).
    /// Optional: empty for dumps that predate the integer path, in which
    /// case [`dense_rate_for_wl`](Self::dense_rate_for_wl) always answers
    /// the f32 rate.
    pub int_rates: Vec<(u32, f64)>,
    /// MAdds/ms through the im2col + packed-GEMM conv lowering (the
    /// `calibration_conv_madds_per_ms` entry). Optional: `None` for dumps
    /// that predate the conv interpreter, in which case conv layers are
    /// charged the dense f32 rate.
    pub conv_madds_per_ms: Option<f64>,
}

impl KernelCalibration {
    /// Parse a `BENCH_native.json` produced by `cargo bench --bench native`.
    pub fn from_bench_json(path: &Path) -> Result<KernelCalibration> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing bench json: {e:?}"))?;
        let derived = json.req("derived").map_err(|e| anyhow!("{e:?}"))?;
        let Json::Obj(map) = derived else {
            return Err(anyhow!("'derived' is not an object"));
        };
        let dense = map
            .get("calibration_dense_madds_per_ms")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("calibration_dense_madds_per_ms missing"))?;
        let mut sparse_rates = Vec::new();
        let mut int_rates = Vec::new();
        for (k, v) in map {
            if let Some(suffix) = k.strip_prefix("calibration_sparse_madds_per_ms_d") {
                let pct: u32 = suffix
                    .parse()
                    .with_context(|| format!("bad density suffix in '{k}'"))?;
                let rate = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("'{k}' is not a number"))?;
                sparse_rates.push((pct as f64 / 100.0, rate));
            } else if let Some(suffix) = k.strip_prefix("calibration_int_madds_per_ms_wl") {
                let wl: u32 = suffix
                    .parse()
                    .with_context(|| format!("bad word-length suffix in '{k}'"))?;
                let rate = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("'{k}' is not a number"))?;
                int_rates.push((wl, rate));
            }
        }
        if sparse_rates.is_empty() {
            return Err(anyhow!("no calibration_sparse_madds_per_ms_d* entries"));
        }
        sparse_rates.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite densities"));
        int_rates.sort_by_key(|r| r.0);
        // a missing key must be an error, not a silent 0.0 — crossover 0
        // would route every layer dense and make the parsed sparse rates
        // unreachable (a bench that measured "sparse never wins" records an
        // explicit 0.0 instead)
        let crossover_density = map
            .get("sparse_crossover_density")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("sparse_crossover_density missing"))?;
        let conv_madds_per_ms = map
            .get("calibration_conv_madds_per_ms")
            .and_then(|v| v.as_f64());
        Ok(KernelCalibration {
            dense_madds_per_ms: dense,
            sparse_rates,
            crossover_density,
            int_rates,
            conv_madds_per_ms,
        })
    }

    /// f32 rate for a layer of `kind`: conv layers (including the strided
    /// 1×1 `downsample` residual projections) run through im2col, so they
    /// earn the measured conv rate when the bench recorded one.
    /// (`pub(crate)`: the drift pass routes through the same table.)
    pub(crate) fn f32_rate_for_kind(&self, kind: &str) -> f64 {
        if kind == "conv" || kind == "downsample" {
            self.conv_madds_per_ms.unwrap_or(self.dense_madds_per_ms)
        } else {
            self.dense_madds_per_ms
        }
    }

    /// Dense-path rate for a layer whose AdaPT word length is `wl`: the
    /// narrowest measured integer rate whose storage width still fits
    /// (the wl08 row covers WL ≤ 8, wl16 covers WL ≤ 16 — the same
    /// width-boundary dispatch `ModelSnapshot` applies at pack time),
    /// else the f32 dense rate.
    pub fn dense_rate_for_wl(&self, wl: u32) -> f64 {
        self.int_rates
            .iter()
            .find(|&&(w, _)| wl <= w)
            .map(|&(_, r)| r)
            .unwrap_or(self.dense_madds_per_ms)
    }

    /// Sparse-kernel rate at `density`, linearly interpolated between the
    /// measured rows and clamped to the measured range. `None` only when no
    /// rows exist (the constructor rejects that).
    pub fn sparse_rate_at(&self, density: f64) -> Option<f64> {
        let rows = &self.sparse_rates;
        let (first, last) = (rows.first()?, rows.last()?);
        if density <= first.0 {
            return Some(first.1);
        }
        if density >= last.0 {
            return Some(last.1);
        }
        for pair in rows.windows(2) {
            let (d0, r0) = pair[0];
            let (d1, r1) = pair[1];
            if density <= d1 {
                let t = if d1 > d0 { (density - d0) / (d1 - d0) } else { 0.0 };
                return Some(r0 + t * (r1 - r0));
            }
        }
        Some(last.1)
    }

    /// Wall-clock inference speedup the MEASURED kernels predict for a
    /// trained run: each layer runs sparse (at its final measured density)
    /// when that density is at or below the benched crossover, else on the
    /// dense path at the rate its final word length earns
    /// ([`dense_rate_for_wl`](Self::dense_rate_for_wl) — i8/i16 when the
    /// bench recorded integer rates, f32 otherwise); the float32 baseline
    /// runs everything dense at the f32 rate. Compare against
    /// `perfmodel::inference_speedup` to see how much of the modelled
    /// speedup survives on the measured kernels.
    pub fn measured_inference_speedup(
        &self,
        layers: &[LayerDesc],
        run: &RunRecord,
    ) -> Option<f64> {
        let nz = sp_rows(run).last()?;
        if nz.len() < layers.len() || self.dense_madds_per_ms <= 0.0 {
            return None;
        }
        let wls = run.layer_wl.last();
        let mut t_f32 = 0.0f64;
        let mut t_q = 0.0f64;
        for (l, desc) in layers.iter().enumerate() {
            let madds = desc.madds as f64;
            let f32_rate = self.f32_rate_for_kind(&desc.kind);
            if f32_rate <= 0.0 {
                return None;
            }
            t_f32 += madds / f32_rate;
            let density = nz[l] as f64;
            let wl = wls.and_then(|w| w.get(l)).map(|&w| w as u32).unwrap_or(32);
            let rate = if density <= self.crossover_density {
                self.sparse_rate_at(density)?
            } else {
                let r = self.dense_rate_for_wl(wl);
                // the wl-fitting int rate wins; a plain-f32 fallback keeps
                // the im2col-aware conv rate instead
                if r == self.dense_madds_per_ms { f32_rate } else { r }
            };
            if rate <= 0.0 {
                return None;
            }
            t_q += madds / rate;
        }
        if t_q > 0.0 {
            Some(t_f32 / t_q)
        } else {
            None
        }
    }
}

/// One measured serving-throughput cell: end-to-end samples/ms through the
/// registry→queue→worker pipeline at a `(max_batch, workers)` setting.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRate {
    pub max_batch: u32,
    pub workers: u32,
    pub samples_per_ms: f64,
}

impl ServeRate {
    /// Fold a live recorder snapshot into a calibration row (wall-clock
    /// throughput — the externally observable rate, matching what the
    /// bench records).
    pub fn from_snapshot(
        max_batch: u32,
        workers: u32,
        snap: &crate::serve::ServeStatsSnapshot,
    ) -> ServeRate {
        ServeRate {
            max_batch,
            workers,
            samples_per_ms: snap.wall_samples_per_ms,
        }
    }
}

/// Measured serving throughput, parsed from `BENCH_serve.json` (module
/// docs) or accumulated from live [`ServeRate`] rows.
#[derive(Debug, Clone)]
pub struct ServeCalibration {
    /// `(max_batch, workers)` cells, as measured.
    pub rates: Vec<ServeRate>,
    /// Cached-snapshot vs rebuild-per-call ablation factor, when the bench
    /// recorded it (how much the persistent pack/CSR cache buys).
    pub pack_cache_speedup: Option<f64>,
}

impl ServeCalibration {
    /// Parse a `BENCH_serve.json` produced by `cargo bench --bench serve`:
    /// requires at least one `serve_samples_per_ms_b<B>_w<W>` derived
    /// entry.
    pub fn from_bench_json(path: &Path) -> Result<ServeCalibration> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing bench json: {e:?}"))?;
        let derived = json.req("derived").map_err(|e| anyhow!("{e:?}"))?;
        let Json::Obj(map) = derived else {
            return Err(anyhow!("'derived' is not an object"));
        };
        let mut rates = Vec::new();
        for (k, v) in map {
            if let Some(suffix) = k.strip_prefix("serve_samples_per_ms_b") {
                let (b_str, w_str) = suffix
                    .split_once("_w")
                    .ok_or_else(|| anyhow!("bad serve rate key '{k}'"))?;
                let max_batch: u32 = b_str
                    .parse()
                    .with_context(|| format!("bad max_batch in '{k}'"))?;
                let workers: u32 = w_str
                    .parse()
                    .with_context(|| format!("bad workers in '{k}'"))?;
                let samples_per_ms = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("'{k}' is not a number"))?;
                rates.push(ServeRate {
                    max_batch,
                    workers,
                    samples_per_ms,
                });
            }
        }
        if rates.is_empty() {
            return Err(anyhow!("no serve_samples_per_ms_b*_w* entries"));
        }
        rates.sort_by_key(|r| (r.max_batch, r.workers));
        let pack_cache_speedup = map.get("serve_pack_cache_speedup").and_then(|v| v.as_f64());
        Ok(ServeCalibration {
            rates,
            pack_cache_speedup,
        })
    }

    /// The best measured cell (highest throughput). `None` never occurs for
    /// parsed calibrations (the constructor rejects empty rate sets).
    pub fn best(&self) -> Option<&ServeRate> {
        self.rates.iter().max_by(|a, b| {
            a.samples_per_ms
                .partial_cmp(&b.samples_per_ms)
                .expect("finite serve rates")
        })
    }

    /// The serving stack's best delivered rate expressed in the kernel
    /// calibration's units (MAdds/ms, via the model's per-sample MAdds),
    /// divided by the measured dense kernel rate: the fraction of raw
    /// kernel throughput that survives batching, queueing and scatter. A
    /// value near 1.0 means the serving layer is free; well above 1.0
    /// means sparse dispatch is winning back more than the pipeline costs.
    pub fn kernel_fraction(
        &self,
        kernels: &KernelCalibration,
        madds_per_sample: f64,
    ) -> Option<f64> {
        if kernels.dense_madds_per_ms <= 0.0 || madds_per_sample <= 0.0 {
            return None;
        }
        let best = self.best()?;
        Some(best.samples_per_ms * madds_per_sample / kernels.dense_madds_per_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StepRow;

    fn write_bench(dir: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        // the shape benches/native.rs emits via write_bench_json
        let text = r#"{
  "derived": {
    "calibration_dense_madds_per_ms": 1000.0,
    "calibration_sparse_madds_per_ms_d10": 4000.0,
    "calibration_sparse_madds_per_ms_d30": 1500.0,
    "calibration_sparse_madds_per_ms_d50": 900.0,
    "sparse_crossover_density": 0.3
  },
  "results": {},
  "unit": "ms_per_iter"
}"#;
        std::fs::write(&path, text).unwrap();
        path
    }

    fn run_with_density(nz: f32) -> RunRecord {
        RunRecord {
            name: "t".into(),
            mode: "adapt".into(),
            batch: 32,
            accs: 1,
            epochs: 1,
            steps_per_epoch: 1,
            num_layers: 2,
            steps: vec![StepRow { loss: 1.0, ce: 1.0, acc: 0.5 }],
            layer_wl: vec![vec![8; 2]],
            layer_nz: vec![vec![nz; 2]],
            ..Default::default()
        }
    }

    fn layers() -> Vec<LayerDesc> {
        vec![
            LayerDesc {
                name: "fc1".into(),
                kind: "dense".into(),
                madds: 100_000,
                weight_elems: 100_000,
                fan_in: 100,
                ..LayerDesc::default()
            },
            LayerDesc {
                name: "fc2".into(),
                kind: "dense".into(),
                madds: 50_000,
                weight_elems: 50_000,
                fan_in: 100,
                ..LayerDesc::default()
            },
        ]
    }

    #[test]
    fn parses_and_interpolates() {
        let path = write_bench("adapt_test_calibration_a");
        let cal = KernelCalibration::from_bench_json(&path).unwrap();
        assert_eq!(cal.dense_madds_per_ms, 1000.0);
        assert_eq!(cal.sparse_rates.len(), 3);
        assert_eq!(cal.crossover_density, 0.3);
        // clamped below/above the measured range
        assert_eq!(cal.sparse_rate_at(0.0), Some(4000.0));
        assert_eq!(cal.sparse_rate_at(0.9), Some(900.0));
        // midpoint of (0.10, 4000) .. (0.30, 1500)
        let mid = cal.sparse_rate_at(0.20).unwrap();
        assert!((mid - 2750.0).abs() < 1e-9, "{mid}");
        // pre-conv dumps carry no conv rate: conv layers charge f32 dense
        assert!(cal.conv_madds_per_ms.is_none());
        assert_eq!(cal.f32_rate_for_kind("conv"), cal.dense_madds_per_ms);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn conv_rate_changes_the_conv_layers_charge_only() {
        let dir = std::env::temp_dir().join("adapt_test_calibration_conv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        let text = r#"{
  "derived": {
    "calibration_dense_madds_per_ms": 1000.0,
    "calibration_conv_madds_per_ms": 600.0,
    "calibration_conv_madds_per_ms_c12x12k5": 580.0,
    "calibration_sparse_madds_per_ms_d10": 4000.0,
    "sparse_crossover_density": 0.05
  },
  "results": {},
  "unit": "ms_per_iter"
}"#;
        std::fs::write(&path, text).unwrap();
        let cal = KernelCalibration::from_bench_json(&path).unwrap();
        assert_eq!(cal.conv_madds_per_ms, Some(600.0));
        // only the exact aggregate key is consumed
        assert_eq!(cal.f32_rate_for_kind("conv"), 600.0);
        // downsample branches are strided 1×1 convs: same im2col rate
        assert_eq!(cal.f32_rate_for_kind("downsample"), 600.0);
        assert_eq!(cal.f32_rate_for_kind("dense"), 1000.0);
        // dense-everywhere run: conv layer costs the conv rate on BOTH
        // sides of the ratio, so an all-dense-path speedup stays 1.0
        let layers = vec![
            LayerDesc {
                name: "conv".into(),
                kind: "conv".into(),
                madds: 100_000,
                weight_elems: 1000,
                fan_in: 9,
                ..LayerDesc::default()
            },
            LayerDesc {
                name: "fc".into(),
                kind: "dense".into(),
                madds: 50_000,
                weight_elems: 50_000,
                fan_in: 100,
                ..LayerDesc::default()
            },
        ];
        let run = run_with_density(0.9); // above crossover: dense path
        let s = cal.measured_inference_speedup(&layers, &run).unwrap();
        assert!((s - 1.0).abs() < 1e-9, "{s}");
        std::fs::remove_file(&path).ok();
    }

    fn write_bench_with_int_rates(dir: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        let text = r#"{
  "derived": {
    "calibration_dense_madds_per_ms": 1000.0,
    "calibration_int_madds_per_ms_wl08": 3000.0,
    "calibration_int_madds_per_ms_wl16": 1500.0,
    "calibration_sparse_madds_per_ms_d10": 4000.0,
    "calibration_sparse_madds_per_ms_d30": 1500.0,
    "calibration_sparse_madds_per_ms_d50": 900.0,
    "sparse_crossover_density": 0.3
  },
  "results": {},
  "unit": "ms_per_iter"
}"#;
        std::fs::write(&path, text).unwrap();
        path
    }

    #[test]
    fn int_rates_are_optional_and_route_by_width_boundary() {
        // a dump from before the integer path: no int keys, every dense
        // layer charges the f32 rate whatever WL says
        let path = write_bench("adapt_test_calibration_noint");
        let cal = KernelCalibration::from_bench_json(&path).unwrap();
        assert!(cal.int_rates.is_empty());
        assert_eq!(cal.dense_rate_for_wl(8), 1000.0);
        std::fs::remove_file(&path).ok();

        let path = write_bench_with_int_rates("adapt_test_calibration_int");
        let cal = KernelCalibration::from_bench_json(&path).unwrap();
        assert_eq!(cal.int_rates, vec![(8, 3000.0), (16, 1500.0)]);
        // same width-boundary dispatch as ModelSnapshot: ≤8 → i8 rate,
        // ≤16 → i16 rate, wider → f32
        assert_eq!(cal.dense_rate_for_wl(6), 3000.0);
        assert_eq!(cal.dense_rate_for_wl(8), 3000.0);
        assert_eq!(cal.dense_rate_for_wl(12), 1500.0);
        assert_eq!(cal.dense_rate_for_wl(24), 1000.0);
        // dense-territory density with final WL 8: the measured model now
        // credits the i8 path, 3000 vs 1000 -> 3x
        let su = cal
            .measured_inference_speedup(&layers(), &run_with_density(0.8))
            .unwrap();
        assert!((su - 3.0).abs() < 1e-9, "{su}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn measured_speedup_uses_sparse_only_below_crossover() {
        let path = write_bench("adapt_test_calibration_b");
        let cal = KernelCalibration::from_bench_json(&path).unwrap();
        let l = layers();
        // dense-territory density: measured speedup is exactly 1 (the CPU
        // cannot cash in WL reduction)
        let su_dense = cal
            .measured_inference_speedup(&l, &run_with_density(0.8))
            .unwrap();
        assert!((su_dense - 1.0).abs() < 1e-12, "{su_dense}");
        // high sparsity: sparse rate 4000 vs dense 1000 -> 4x
        let su_sparse = cal
            .measured_inference_speedup(&l, &run_with_density(0.1))
            .unwrap();
        assert!((su_sparse - 4.0).abs() < 1e-9, "{su_sparse}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serve_calibration_parses_and_compares() {
        let dir = std::env::temp_dir().join("adapt_test_calibration_serve");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        std::fs::write(
            &path,
            r#"{
  "derived": {
    "serve_samples_per_ms_b1_w1": 2.0,
    "serve_samples_per_ms_b32_w1": 8.0,
    "serve_samples_per_ms_b32_w4": 20.0,
    "serve_pack_cache_speedup": 3.5
  },
  "results": {},
  "unit": "ms_per_iter"
}"#,
        )
        .unwrap();
        let cal = ServeCalibration::from_bench_json(&path).unwrap();
        assert_eq!(cal.rates.len(), 3);
        assert_eq!(cal.pack_cache_speedup, Some(3.5));
        let best = cal.best().unwrap();
        assert_eq!((best.max_batch, best.workers), (32, 4));
        // kernel comparison: 20 samples/ms × 100 madds/sample over a
        // 1000 madds/ms dense kernel -> the stack delivers 2x the dense
        // kernel rate (sparse dispatch winning back more than overhead)
        let kpath = write_bench("adapt_test_calibration_serve_k");
        let kc = KernelCalibration::from_bench_json(&kpath).unwrap();
        let frac = cal.kernel_fraction(&kc, 100.0).unwrap();
        assert!((frac - 2.0).abs() < 1e-12, "{frac}");
        std::fs::remove_file(&kpath).ok();

        // no serve entries at all -> error, never an empty calibration
        std::fs::write(&path, r#"{"derived": {"other": 1.0}, "results": {}}"#).unwrap();
        assert!(ServeCalibration::from_bench_json(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serve_rate_from_snapshot_uses_wall_rate() {
        let snap = crate::serve::ServeStatsSnapshot {
            samples: 100,
            wall_samples_per_ms: 12.5,
            ..Default::default()
        };
        let r = ServeRate::from_snapshot(16, 2, &snap);
        assert_eq!(r.max_batch, 16);
        assert_eq!(r.workers, 2);
        assert_eq!(r.samples_per_ms, 12.5);
    }

    #[test]
    fn missing_sections_are_errors() {
        let dir = std::env::temp_dir().join("adapt_test_calibration_c");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        std::fs::write(&path, r#"{"derived": {}, "results": {}}"#).unwrap();
        assert!(KernelCalibration::from_bench_json(&path).is_err());
        // rates present but no measured crossover: also an error, never a
        // silent crossover of 0.0
        std::fs::write(
            &path,
            r#"{"derived": {"calibration_dense_madds_per_ms": 1000.0,
                "calibration_sparse_madds_per_ms_d10": 4000.0}, "results": {}}"#,
        )
        .unwrap();
        assert!(KernelCalibration::from_bench_json(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
