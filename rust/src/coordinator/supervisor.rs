//! Crash-resumable, self-healing training runs.
//!
//! The supervisor owns the train loop (same semantics as
//! `trainer::train_with_data`, verified bit-identical by
//! `trainer_e2e::supervisor_matches_plain_trainer_bitwise`) and layers three
//! robustness mechanisms on top:
//!
//! 1. **Full-state checkpoints.** Every `every_steps` steps (plus a step-0
//!    baseline) the complete run state — master tensors, controller formats
//!    and PushUp windows, pending switch events, data-order RNG, LR
//!    scheduler, epoch/step cursors and the `RunRecord` prefix — is
//!    serialized into the v2 `ADPT` aux section and written atomically by a
//!    background thread. A ring of the newest `keep` checkpoints is
//!    retained. Killing the process after step N and re-running with the
//!    same config resumes from the newest loadable checkpoint and produces
//!    a bit-identical trajectory to an uninterrupted run.
//!
//! 2. **Divergence rollback.** When a step reports a non-finite (or
//!    over-threshold) loss/CE/gradient norm, the supervisor restores the
//!    newest loadable checkpoint and applies a forced whole-net PushUp —
//!    the paper's vanishing-gradient guard (sec. 3.3) used as a repair:
//!    replayed steps get more fractional bits, so gradients that underflowed
//!    to garbage at the old format survive at the new one. The recovered
//!    state is immediately re-checkpointed under the same tag so repeated
//!    rollbacks escalate precision instead of replaying one image. After
//!    `max_rollbacks` recoveries the run fails with a typed
//!    [`RunAborted`] — never a panic, never a silently wrong result.
//!
//! 3. **Deterministic fault injection.** A [`FaultPlan`] (env:
//!    `ADAPT_FAULTS`) fires NaN losses, checkpoint corruption and simulated
//!    crashes at exact step / write-ordinal indices, so every recovery path
//!    above is exercised by deterministic tests rather than luck.
//!
//! The loop batches with the synchronous `Batcher` (bit-identical to the
//! `PrefetchLoader`, pinned by `data::loader::tests::prefetch_matches_sync`)
//! because resume needs a snapshotable data-order cursor.

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::data::{Batcher, Dataset};
use crate::init;
use crate::metrics::{RunRecord, StepRow, SwitchEventLite};
use crate::quant::{QuantController, QuantPool};
use crate::runtime::{LoadedModel, Manifest, TrainState};
use crate::telemetry::{spans, Event, TelemetrySink};
use crate::util::blob::{BlobReader, BlobWriter};

use super::checkpoint;
use super::faults::{corrupt_image, FaultKind, FaultPlan};
use super::scheduler::LrSchedule;
use super::trainer::{
    datasets_for, emit_new_switches, evaluate, make_controller, Policy, TrainConfig, TrainOutcome,
};

/// Version tag of the supervisor's aux-section layout.
const AUX_VERSION: u32 = 1;

/// Supervision knobs; everything has a production-sane default.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Directory holding the checkpoint ring (`ckpt_<step>.adpt`).
    pub ckpt_dir: PathBuf,
    /// Checkpoint every n global steps; 0 disables periodic checkpoints
    /// (the step-0 baseline is still written so rollback has a target).
    pub every_steps: u64,
    /// Number of newest checkpoints retained in the ring.
    pub keep: usize,
    /// Divergence recoveries allowed before the run aborts.
    pub max_rollbacks: u32,
    /// CE above this value counts as divergence even when finite
    /// (default: infinity — only non-finite metrics trigger).
    pub divergence_ce: f32,
    /// Word-length bits added by the forced recovery PushUp.
    pub push_up_bump: u8,
    /// Injected faults (empty in production).
    pub faults: Arc<FaultPlan>,
}

impl SupervisorConfig {
    pub fn new(ckpt_dir: impl Into<PathBuf>) -> Self {
        SupervisorConfig {
            ckpt_dir: ckpt_dir.into(),
            every_steps: 25,
            keep: 3,
            max_rollbacks: 3,
            divergence_ce: f32::INFINITY,
            push_up_bump: 4,
            faults: FaultPlan::none(),
        }
    }

    /// Defaults, with the fault plan (`ADAPT_FAULTS`), checkpoint cadence
    /// (`ADAPT_CKPT_EVERY`) and rollback budget (`ADAPT_MAX_ROLLBACKS`)
    /// taken from the environment when set.
    pub fn from_env(ckpt_dir: impl Into<PathBuf>) -> Result<Self> {
        let mut cfg = SupervisorConfig::new(ckpt_dir);
        cfg.faults = FaultPlan::from_env()?;
        if let Ok(v) = std::env::var("ADAPT_CKPT_EVERY") {
            cfg.every_steps = v.parse().context("bad ADAPT_CKPT_EVERY")?;
        }
        if let Ok(v) = std::env::var("ADAPT_MAX_ROLLBACKS") {
            cfg.max_rollbacks = v.parse().context("bad ADAPT_MAX_ROLLBACKS")?;
        }
        Ok(cfg)
    }
}

/// Terminal outcome of an exhausted rollback budget.
#[derive(Debug, Clone)]
pub struct RunAborted {
    /// Global step (1-based) whose metrics diverged last.
    pub step: u64,
    /// Recoveries performed before giving up.
    pub rollbacks: u32,
    /// The CE that triggered the final abort (typically NaN).
    pub last_ce: f32,
}

/// Typed supervisor failures.
#[derive(Debug)]
pub enum SupervisorError {
    /// Divergence persisted through every allowed rollback.
    Aborted(RunAborted),
    /// A `step:N=crash` fault fired — the simulated process kill. The
    /// checkpoint ring on disk is synced before this returns, so a
    /// follow-up run resumes exactly.
    InjectedCrash { step: u64 },
    /// Underlying training/runtime failure.
    Train(anyhow::Error),
}

impl fmt::Display for SupervisorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SupervisorError::Aborted(a) => write!(
                f,
                "run aborted: step {} still diverged (ce {}) after {} rollbacks",
                a.step, a.last_ce, a.rollbacks
            ),
            SupervisorError::InjectedCrash { step } => {
                write!(f, "injected crash after step {step}")
            }
            SupervisorError::Train(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SupervisorError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SupervisorError::Train(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl From<anyhow::Error> for SupervisorError {
    fn from(e: anyhow::Error) -> Self {
        SupervisorError::Train(e)
    }
}

/// A finished supervised run plus its recovery telemetry.
pub struct SupervisedOutcome {
    pub outcome: TrainOutcome,
    /// Divergence recoveries performed.
    pub rollbacks: u32,
    /// Checkpoint images written (including the step-0 baseline).
    pub checkpoints: u64,
    /// Step tag of the checkpoint this run resumed from, if any.
    pub resumed_from: Option<u64>,
}

// ---------------------------------------------------------------------------
// Checkpoint ring + background writer

/// On-disk ring of `ckpt_<step>.adpt` files, newest `keep` retained.
struct CkptRing {
    dir: PathBuf,
    keep: usize,
    /// (step tag, path), sorted ascending by tag.
    entries: Vec<(u64, PathBuf)>,
    /// Write ordinal — the `ckpt:` fault-injection site.
    writes: u64,
}

impl CkptRing {
    fn scan(dir: &Path, keep: usize) -> CkptRing {
        let mut entries = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if let Some(tag) = name
                    .strip_prefix("ckpt_")
                    .and_then(|s| s.strip_suffix(".adpt"))
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    entries.push((tag, e.path()));
                }
            }
        }
        entries.sort_by_key(|(t, _)| *t);
        CkptRing {
            dir: dir.to_path_buf(),
            keep: keep.max(1),
            entries,
            writes: 0,
        }
    }

    fn path_for(&self, tag: u64) -> PathBuf {
        self.dir.join(format!("ckpt_{tag:012}.adpt"))
    }

    /// Register a write of `tag`; returns its path plus the paths evicted
    /// from the ring (oldest first). Re-writing an existing tag (the
    /// post-rollback escalation) evicts nothing.
    fn record(&mut self, tag: u64) -> (PathBuf, Vec<PathBuf>) {
        let path = self.path_for(tag);
        if !self.entries.iter().any(|(t, _)| *t == tag) {
            self.entries.push((tag, path.clone()));
            self.entries.sort_by_key(|(t, _)| *t);
        }
        let mut evict = Vec::new();
        while self.entries.len() > self.keep {
            let (_, p) = self.entries.remove(0);
            if p != path {
                evict.push(p);
            }
        }
        (path, evict)
    }
}

enum WriterCmd {
    Write {
        bytes: Vec<u8>,
        path: PathBuf,
        evict: Vec<PathBuf>,
    },
    Sync(mpsc::Sender<()>),
}

/// Dedicated checkpoint-writer thread: the hot path serializes into a
/// buffer and hands it off; disk latency never stalls a training step.
struct CkptWriter {
    tx: Option<mpsc::Sender<WriterCmd>>,
    handle: Option<thread::JoinHandle<()>>,
    errors: Arc<Mutex<Vec<String>>>,
}

impl CkptWriter {
    fn spawn() -> CkptWriter {
        let (tx, rx) = mpsc::channel::<WriterCmd>();
        let errors = Arc::new(Mutex::new(Vec::new()));
        let errs = errors.clone();
        let handle = thread::spawn(move || {
            for cmd in rx {
                match cmd {
                    WriterCmd::Write { bytes, path, evict } => {
                        if let Err(e) = checkpoint::write_atomic(&bytes, &path) {
                            errs.lock().unwrap().push(format!("{}: {e}", path.display()));
                        }
                        for p in evict {
                            let _ = std::fs::remove_file(p);
                        }
                    }
                    WriterCmd::Sync(done) => {
                        let _ = done.send(());
                    }
                }
            }
        });
        CkptWriter {
            tx: Some(tx),
            handle: Some(handle),
            errors,
        }
    }

    fn write(&self, bytes: Vec<u8>, path: PathBuf, evict: Vec<PathBuf>) {
        let _ = self
            .tx
            .as_ref()
            .expect("writer alive")
            .send(WriterCmd::Write { bytes, path, evict });
    }

    /// Block until every enqueued write hit disk; drain accumulated errors.
    fn sync(&self) -> Vec<String> {
        let (dtx, drx) = mpsc::channel();
        if self
            .tx
            .as_ref()
            .expect("writer alive")
            .send(WriterCmd::Sync(dtx))
            .is_ok()
        {
            let _ = drx.recv();
        }
        std::mem::take(&mut *self.errors.lock().unwrap())
    }
}

impl Drop for CkptWriter {
    fn drop(&mut self) {
        self.tx.take(); // close the channel so the thread drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Aux blob: the full run state beyond the tensors

/// Run state restored from a checkpoint's aux section.
struct AuxState {
    rec: RunRecord,
    schedule: Option<LrSchedule>,
    lr: f32,
    global_step: u64,
    epoch: usize,
    done: usize,
}

#[allow(clippy::too_many_arguments)]
fn encode_aux(
    controller: &dyn QuantController,
    schedule: &Option<LrSchedule>,
    lr: f32,
    batcher: &Batcher,
    rec: &RunRecord,
    global_step: u64,
    epoch: usize,
    done: usize,
) -> Vec<u8> {
    let mut w = BlobWriter::new();
    w.u32(AUX_VERSION);
    w.str_lp(controller.name());
    w.u64(global_step);
    w.u64(epoch as u64);
    w.u64(done as u64);
    w.f32_bits(lr);
    match schedule {
        Some(s) => {
            w.u8(1);
            s.save_state(&mut w);
        }
        None => w.u8(0),
    }
    batcher.save_state(&mut w);
    let mut cw = BlobWriter::new();
    controller.save_state(&mut cw);
    w.bytes_lp(&cw.into_vec());
    let mut rw = BlobWriter::new();
    rec.save_state(&mut rw);
    w.bytes_lp(&rw.into_vec());
    w.into_vec()
}

fn decode_aux(
    aux: &[u8],
    expect_schedule: bool,
    controller: &mut dyn QuantController,
    batcher: &mut Batcher,
) -> Result<AuxState> {
    let mut r = BlobReader::new(aux);
    let v = r.u32()?;
    ensure!(v == AUX_VERSION, "unknown supervisor aux version {v}");
    let name = r.str_lp()?;
    ensure!(
        name == controller.name(),
        "checkpoint was written by the `{name}` policy, this run uses `{}`",
        controller.name()
    );
    let global_step = r.u64()?;
    let epoch = r.u64()? as usize;
    let done = r.u64()? as usize;
    let lr = r.f32_bits()?;
    let schedule = match r.u8()? {
        0 => None,
        1 => Some(LrSchedule::load_state(&mut r)?),
        t => bail!("bad schedule presence byte {t}"),
    };
    ensure!(
        schedule.is_some() == expect_schedule,
        "checkpoint lr-schedule presence does not match the run config"
    );
    batcher.load_state(&mut r)?;
    let cb = r.bytes_lp()?;
    let mut cr = BlobReader::new(cb);
    controller.load_state(&mut cr)?;
    ensure!(
        cr.is_empty(),
        "controller snapshot has {} trailing bytes",
        cr.remaining()
    );
    let rb = r.bytes_lp()?;
    let mut rr = BlobReader::new(rb);
    let rec = RunRecord::load_state(&mut rr)?;
    ensure!(
        rr.is_empty(),
        "run-record snapshot has {} trailing bytes",
        rr.remaining()
    );
    ensure!(r.is_empty(), "supervisor aux has {} trailing bytes", r.remaining());
    Ok(AuxState {
        rec,
        schedule,
        lr,
        global_step,
        epoch,
        done,
    })
}

/// Load + fully validate one checkpoint into a fresh controller/batcher.
fn try_restore(
    path: &Path,
    man: &Manifest,
    expect_schedule: bool,
    controller: &mut dyn QuantController,
    batcher: &mut Batcher,
) -> Result<(TrainState, AuxState)> {
    let ck = checkpoint::load_full(path).map_err(|e| anyhow!("{e}"))?;
    ensure!(
        ck.version >= 2,
        "v{} checkpoints carry no run state to resume from",
        ck.version
    );
    checkpoint::validate_against(&ck.state, man)?;
    let aux = decode_aux(&ck.aux, expect_schedule, controller, batcher)?;
    Ok((ck.state, aux))
}

/// Walk the ring newest-first and restore the first checkpoint that loads
/// and validates end to end. Each attempt gets a *fresh* controller and
/// batcher so a half-applied failure cannot leak into the next attempt; on
/// success they replace the caller's.
fn restore_latest(
    entries: &[(u64, PathBuf)],
    man: &Manifest,
    cfg: &TrainConfig,
    pool: &Option<Arc<QuantPool>>,
    data: &Arc<dyn Dataset>,
    controller: &mut Box<dyn QuantController>,
    batcher: &mut Batcher,
) -> Option<(u64, TrainState, AuxState)> {
    for (tag, path) in entries.iter().rev() {
        let mut c = make_controller(&cfg.policy, man, pool);
        let mut b = Batcher::new(data.clone(), man.batch, cfg.seed ^ 0xBA7C4);
        match try_restore(path, man, cfg.lr_schedule.is_some(), &mut *c, &mut b) {
            Ok((state, aux)) => {
                *controller = c;
                *batcher = b;
                return Some((*tag, state, aux));
            }
            Err(e) => {
                eprintln!(
                    "[supervisor] checkpoint {} unusable ({e}); trying older",
                    path.display()
                );
            }
        }
    }
    None
}

fn enqueue_checkpoint(
    writer: &CkptWriter,
    ring: &mut CkptRing,
    faults: &FaultPlan,
    sink: &TelemetrySink,
    state: &TrainState,
    aux: &[u8],
    tag: u64,
) {
    let mut bytes = checkpoint::encode(state, aux);
    if let Some(f) = faults.ckpt_fault(ring.writes) {
        eprintln!(
            "[supervisor] injecting checkpoint fault {f:?} at write ordinal {}",
            ring.writes
        );
        sink.emit(&Event::Fault {
            step: tag,
            kind: format!("{f:?}"),
        });
        corrupt_image(&mut bytes, f);
    }
    ring.writes += 1;
    let (path, evict) = ring.record(tag);
    writer.write(bytes, path, evict);
    sink.emit(&Event::Checkpoint { step: tag });
}

// ---------------------------------------------------------------------------
// The supervised loop

/// [`supervise`] with datasets derived from the manifest, mirroring
/// `train_via_model`.
pub fn supervise_via_model(
    model: &LoadedModel,
    cfg: &TrainConfig,
    sup: &SupervisorConfig,
) -> Result<SupervisedOutcome, SupervisorError> {
    let (data, eval) = datasets_for(&model.manifest, cfg.train_size, cfg.eval_size, cfg.seed)?;
    supervise(model, cfg, sup, data, eval)
}

/// [`supervise_with_telemetry`] with datasets derived from the manifest.
pub fn supervise_via_model_telemetry(
    model: &LoadedModel,
    cfg: &TrainConfig,
    sup: &SupervisorConfig,
    sink: &TelemetrySink,
) -> Result<SupervisedOutcome, SupervisorError> {
    let (data, eval) = datasets_for(&model.manifest, cfg.train_size, cfg.eval_size, cfg.seed)?;
    supervise_with_telemetry(model, cfg, sup, data, eval, sink)
}

/// Run a crash-resumable, self-healing training loop. Without faults and
/// without pre-existing checkpoints this produces a trajectory bit-identical
/// to `train_with_data`; with a populated `ckpt_dir` it resumes the run
/// from the newest loadable checkpoint.
pub fn supervise(
    model: &LoadedModel,
    cfg: &TrainConfig,
    sup: &SupervisorConfig,
    data: Arc<dyn Dataset>,
    eval: Arc<dyn Dataset>,
) -> Result<SupervisedOutcome, SupervisorError> {
    supervise_with_telemetry(model, cfg, sup, data, eval, &TelemetrySink::disabled())
}

/// [`supervise`] with the full recovery story mirrored into the event log:
/// fault injections, checkpoint enqueues, rollbacks (with the restored
/// trajectory lengths, so [`crate::telemetry::replay`] can rewind exactly
/// the way the in-memory `RunRecord` did) and resumes.
pub fn supervise_with_telemetry(
    model: &LoadedModel,
    cfg: &TrainConfig,
    sup: &SupervisorConfig,
    data: Arc<dyn Dataset>,
    eval: Arc<dyn Dataset>,
    sink: &TelemetrySink,
) -> Result<SupervisedOutcome, SupervisorError> {
    let man = &model.manifest;
    if data.input_shape() != (man.input_shape[0], man.input_shape[1], man.input_shape[2]) {
        return Err(anyhow!("dataset shape mismatch with artifact").into());
    }
    let batch = man.batch;
    let steps_per_epoch = (data.len() / batch).max(1);
    // Same pool policy as the trainer: reuse the backend's team for AdaPT.
    let pool: Option<Arc<QuantPool>> = match &cfg.policy {
        Policy::Adapt(_) => Some(
            model
                .pool
                .clone()
                .unwrap_or_else(|| Arc::new(QuantPool::with_default_threads())),
        ),
        _ => None,
    };
    let mut controller = make_controller(&cfg.policy, man, &pool);

    let mut state = TrainState {
        params: init::init_params(man, cfg.init, cfg.init_scale, cfg.seed),
        gsum: init::init_gsum(man),
        bn: init::init_bn(man),
        step: cfg.seed.wrapping_mul(7919) % (1 << 20), // decorrelate PRNG streams
    };
    let mut batcher = Batcher::new(data.clone(), batch, cfg.seed ^ 0xBA7C4);
    let mut hyper = cfg.hyper;
    let mut schedule = cfg.lr_schedule.clone();
    if let Some(sch) = &schedule {
        hyper.lr = sch.current();
    }
    let mut rec = RunRecord {
        name: cfg.artifact.clone(),
        mode: cfg.policy.mode_name().to_string(),
        batch,
        accs: cfg.accs,
        epochs: cfg.epochs,
        steps_per_epoch,
        num_layers: man.num_layers,
        ..Default::default()
    };
    let mut global_step = 0u64;
    let mut epoch = 0usize;
    let mut done = 0usize; // steps completed within the current epoch

    let mut ring = CkptRing::scan(&sup.ckpt_dir, sup.keep);
    let writer = CkptWriter::spawn();
    let mut rollbacks = 0u32;
    let mut resumed_from = None;

    if let Some((tag, st, aux)) = restore_latest(
        &ring.entries,
        man,
        cfg,
        &pool,
        &data,
        &mut controller,
        &mut batcher,
    ) {
        state = st;
        rec = aux.rec;
        hyper.lr = aux.lr;
        schedule = aux.schedule;
        global_step = aux.global_step;
        epoch = aux.epoch;
        done = aux.done;
        resumed_from = Some(tag);
        eprintln!(
            "[supervisor] resumed {} from checkpoint step {tag} (epoch {epoch}, {done}/{steps_per_epoch})",
            cfg.artifact
        );
    } else if !ring.entries.is_empty() {
        eprintln!(
            "[supervisor] no loadable checkpoint in {}; starting fresh",
            sup.ckpt_dir.display()
        );
    }

    let telemetry = sink.is_enabled();
    let mut emitted_switches = 0usize;
    if telemetry {
        sink.emit(&Event::RunStart {
            name: rec.name.clone(),
            mode: rec.mode.clone(),
            batch,
            accs: cfg.accs,
            epochs: cfg.epochs,
            steps_per_epoch,
            num_layers: man.num_layers,
        });
        if let Some(tag) = resumed_from {
            // The restored pending events were already logged by the run
            // that wrote the checkpoint — start the high-water mark there.
            emitted_switches = controller.pending_events().len();
            sink.emit(&Event::Resume {
                from_step: tag,
                steps: rec.steps.len(),
                evals: rec.evals.len(),
                switches: emitted_switches,
            });
        }
    }
    spans::set_enabled(telemetry);

    if resumed_from.is_none() {
        // Step-0 baseline: the first rollback always has a target, even
        // before the first periodic checkpoint (or with every_steps = 0).
        let aux = encode_aux(
            &*controller,
            &schedule,
            hyper.lr,
            &batcher,
            &rec,
            global_step,
            epoch,
            done,
        );
        enqueue_checkpoint(&writer, &mut ring, &sup.faults, sink, &state, &aux, global_step);
    }

    let t0 = Instant::now();
    'outer: while epoch < cfg.epochs {
        while done < steps_per_epoch {
            let b = batcher.next_batch();
            let qp = controller.qparams();
            let mut m = model.train_step(&mut state, &b.x, &b.y, &qp, &hyper)?;
            let this_step = global_step + 1;
            if sup.faults.fire(FaultKind::NanLoss, this_step) {
                eprintln!("[supervisor] injecting NaN loss at step {this_step}");
                sink.emit(&Event::Fault {
                    step: this_step,
                    kind: format!("{:?}", FaultKind::NanLoss),
                });
                m.loss = f32::NAN;
                m.ce = f32::NAN;
                m.grad_norm.iter_mut().for_each(|g| *g = f32::NAN);
            }
            let diverged = !m.loss.is_finite()
                || !m.ce.is_finite()
                || m.ce > sup.divergence_ce
                || m.grad_norm
                    .iter()
                    .chain(m.gsum_norm.iter())
                    .any(|v| !v.is_finite());
            if diverged {
                if rollbacks >= sup.max_rollbacks {
                    return Err(SupervisorError::Aborted(RunAborted {
                        step: this_step,
                        rollbacks,
                        last_ce: m.ce,
                    }));
                }
                rollbacks += 1;
                for e in writer.sync() {
                    eprintln!("[supervisor] checkpoint write failed: {e}");
                }
                let Some((tag, st, aux)) = restore_latest(
                    &ring.entries,
                    man,
                    cfg,
                    &pool,
                    &data,
                    &mut controller,
                    &mut batcher,
                ) else {
                    return Err(SupervisorError::Aborted(RunAborted {
                        step: this_step,
                        rollbacks,
                        last_ce: m.ce,
                    }));
                };
                state = st;
                rec = aux.rec;
                hyper.lr = aux.lr;
                schedule = aux.schedule;
                global_step = aux.global_step;
                epoch = aux.epoch;
                done = aux.done;
                if telemetry {
                    // Rewind the switch high-water mark to what the restored
                    // checkpoint carries; the forced PushUp below then logs
                    // as a fresh Switch AFTER the Rollback marker.
                    emitted_switches = controller.pending_events().len();
                    sink.emit(&Event::Rollback {
                        step: this_step,
                        to_step: tag,
                        rollbacks: rollbacks as u64,
                        steps: rec.steps.len(),
                        evals: rec.evals.len(),
                        switches: emitted_switches,
                    });
                    // diverged-step span residue must not leak into replays
                    spans::take();
                }
                let raised = controller.force_push_up(&mut state, sup.push_up_bump);
                eprintln!(
                    "[supervisor] step {this_step} diverged (ce {}): rolled back to step {tag} \
                     (rollback {rollbacks}/{}), precision {}",
                    m.ce,
                    sup.max_rollbacks,
                    if raised { "raised" } else { "unchanged" }
                );
                // Persist the recovered+raised state under the same tag so
                // the next rollback escalates instead of replaying this image.
                let aux2 = encode_aux(
                    &*controller,
                    &schedule,
                    hyper.lr,
                    &batcher,
                    &rec,
                    global_step,
                    epoch,
                    done,
                );
                enqueue_checkpoint(&writer, &mut ring, &sup.faults, sink, &state, &aux2, global_step);
                if telemetry {
                    emit_new_switches(sink, controller.pending_events(), &mut emitted_switches);
                    // make the recovery durable in the log before replaying
                    for e in sink.sync() {
                        eprintln!("[telemetry] write error: {e}");
                    }
                }
                continue 'outer;
            }

            controller.on_step(&mut state, &m);
            global_step += 1;
            done += 1;
            rec.steps.push(StepRow {
                loss: m.loss,
                ce: m.ce,
                acc: m.acc,
            });
            rec.layer_wl.push(controller.wordlengths());
            rec.layer_nz
                .push(m.sparsity.iter().map(|&s| 1.0 - s).collect());
            let lb = controller.lookbacks();
            if !lb.is_empty() {
                rec.layer_lb.push(lb);
                rec.layer_res.push(controller.resolutions());
            }
            let wnz = controller.weight_nz();
            if !wnz.is_empty() {
                rec.layer_wnz.push(wnz);
                rec.layer_wmax.push(controller.weight_max_abs());
            }
            if telemetry {
                let timing = spans::take();
                sink.emit(&Event::Step {
                    step: global_step,
                    epoch,
                    loss: m.loss,
                    ce: m.ce,
                    acc: m.acc,
                    gnorm: m.grad_norm.iter().cloned().fold(0.0, f32::max),
                    wl: controller.wordlengths(),
                    nz: m.sparsity.iter().map(|&s| 1.0 - s).collect(),
                    lb: controller.lookbacks(),
                    res: controller.resolutions(),
                    wnz: controller.weight_nz(),
                    wmax: controller.weight_max_abs(),
                });
                emit_new_switches(sink, controller.pending_events(), &mut emitted_switches);
                sink.emit(&Event::StepTiming {
                    step: global_step,
                    quant_ms: timing[spans::Phase::Quant as usize],
                    gemm_ms: timing[spans::Phase::Gemm as usize],
                    pack_ms: timing[spans::Phase::Pack as usize],
                    epilogue_ms: timing[spans::Phase::Epilogue as usize],
                });
            }
            if cfg.log_every > 0 && global_step % cfg.log_every as u64 == 0 {
                eprintln!(
                    "[{}/{}] epoch {epoch} step {global_step}: loss {:.4} acc {:.3} wl {:?}",
                    cfg.artifact,
                    controller.name(),
                    m.loss,
                    m.acc,
                    controller.wordlengths()
                );
            }
            if sup.every_steps > 0 && global_step % sup.every_steps == 0 {
                let aux = encode_aux(
                    &*controller,
                    &schedule,
                    hyper.lr,
                    &batcher,
                    &rec,
                    global_step,
                    epoch,
                    done,
                );
                enqueue_checkpoint(&writer, &mut ring, &sup.faults, sink, &state, &aux, global_step);
            }
            if sup.faults.fire(FaultKind::Crash, global_step) {
                for e in writer.sync() {
                    eprintln!("[supervisor] checkpoint write failed: {e}");
                }
                if telemetry {
                    sink.emit(&Event::Fault {
                        step: global_step,
                        kind: format!("{:?}", FaultKind::Crash),
                    });
                    for e in sink.sync() {
                        eprintln!("[telemetry] write error: {e}");
                    }
                    spans::set_enabled(false);
                }
                return Err(SupervisorError::InjectedCrash { step: global_step });
            }
        }
        let t_sync = Instant::now();
        controller.on_epoch_end(&mut state, epoch);
        let sync_secs = t_sync.elapsed().as_secs_f64();
        rec.switch_secs += sync_secs;
        if telemetry {
            sink.emit(&Event::EpochEnd { epoch, sync_secs });
            emit_new_switches(sink, controller.pending_events(), &mut emitted_switches);
        }
        if let Some(sch) = &mut schedule {
            let tail = &rec.steps[rec.steps.len() - steps_per_epoch..];
            let mean_loss = tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32;
            hyper.lr = sch.on_epoch(mean_loss);
        }
        let last = epoch + 1 == cfg.epochs;
        if last || (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0) {
            let acc = evaluate(model, &state, &controller.qparams(), eval.as_ref())?;
            rec.evals.push((global_step, acc));
            if telemetry {
                // eval inference spans are not training step time
                spans::take();
                sink.emit(&Event::Eval {
                    step: global_step,
                    acc,
                });
            }
            if cfg.log_every > 0 {
                eprintln!(
                    "[{}/{}] epoch {epoch}: EVAL acc {acc:.4}",
                    cfg.artifact,
                    controller.name()
                );
            }
        }
        epoch += 1;
        done = 0;
    }

    for e in writer.sync() {
        eprintln!("[supervisor] checkpoint write failed: {e}");
    }
    rec.switches = controller
        .take_events()
        .iter()
        .map(SwitchEventLite::from)
        .collect();
    rec.wall_secs += t0.elapsed().as_secs_f64();
    if telemetry {
        sink.emit(&Event::RunEnd {
            steps: rec.steps.len(),
            wall_secs: rec.wall_secs,
            switch_secs: rec.switch_secs,
            final_ce: rec.steps.last().map(|s| s.ce).unwrap_or(0.0),
        });
        for e in sink.sync() {
            eprintln!("[telemetry] write error: {e}");
        }
        spans::set_enabled(false);
    }
    let final_qparams = controller.qparams();
    let final_wordlengths = controller.wordlengths();
    Ok(SupervisedOutcome {
        outcome: TrainOutcome {
            record: rec,
            state,
            final_qparams,
            final_wordlengths,
        },
        rollbacks,
        checkpoints: ring.writes,
        resumed_from,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticVision;
    use crate::runtime::manifest::test_mlp_manifest;

    fn tmpdir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("adapt_sup_{name}_{}", std::process::id()))
    }

    #[test]
    fn aux_round_trip_restores_every_cursor() {
        let man = test_mlp_manifest();
        let data: Arc<dyn Dataset> = Arc::new(SyntheticVision::mnist_like(64, 0));
        let cfg = TrainConfig::fast("mlp", Policy::Float32);
        let controller = make_controller(&cfg.policy, &man, &None);
        let mut batcher = Batcher::new(data.clone(), 8, 42);
        for _ in 0..5 {
            batcher.next_batch();
        }
        let schedule = Some(LrSchedule::rop(0.05, 0.5, 2, 1e-3));
        let mut rec = RunRecord {
            name: "mlp".into(),
            mode: "float32".into(),
            ..Default::default()
        };
        rec.steps.push(StepRow {
            loss: 1.5,
            ce: 1.25,
            acc: 0.5,
        });
        let aux = encode_aux(&*controller, &schedule, 0.025, &batcher, &rec, 17, 2, 3);

        let mut c2 = make_controller(&cfg.policy, &man, &None);
        let mut b2 = Batcher::new(data.clone(), 8, 999);
        let st = decode_aux(&aux, true, &mut *c2, &mut b2).unwrap();
        assert_eq!(st.global_step, 17);
        assert_eq!(st.epoch, 2);
        assert_eq!(st.done, 3);
        assert_eq!(st.lr.to_bits(), 0.025f32.to_bits());
        assert!(st.schedule.is_some());
        assert_eq!(st.rec.steps.len(), 1);
        assert_eq!(st.rec.steps[0].ce.to_bits(), 1.25f32.to_bits());
        // restored batcher continues the original stream
        let mut b3 = Batcher::new(data, 8, 42);
        for _ in 0..5 {
            b3.next_batch();
        }
        let a = b3.next_batch();
        let b = b2.next_batch();
        assert_eq!(a.y, b.y);

        // policy mismatch is a typed refusal, not garbage state
        let man2 = test_mlp_manifest();
        let mut wrong = make_controller(
            &Policy::Muppet(crate::muppet::MuppetHyper::default()),
            &man2,
            &None,
        );
        let mut b4 = Batcher::new(Arc::new(SyntheticVision::mnist_like(64, 0)), 8, 1);
        assert!(decode_aux(&aux, true, &mut *wrong, &mut b4).is_err());
        // schedule presence mismatch likewise
        let mut c3 = make_controller(&cfg.policy, &man, &None);
        let mut b5 = Batcher::new(Arc::new(SyntheticVision::mnist_like(64, 0)), 8, 1);
        assert!(decode_aux(&aux, false, &mut *c3, &mut b5).is_err());
    }

    #[test]
    fn ring_scans_sorted_and_evicts_oldest() {
        let dir = tmpdir("ring");
        std::fs::create_dir_all(&dir).unwrap();
        for tag in [30u64, 10, 20] {
            std::fs::write(dir.join(format!("ckpt_{tag:012}.adpt")), b"x").unwrap();
        }
        std::fs::write(dir.join("not_a_ckpt.txt"), b"x").unwrap();
        let mut ring = CkptRing::scan(&dir, 3);
        assert_eq!(
            ring.entries.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
            vec![10, 20, 30]
        );
        let (_, evict) = ring.record(40);
        assert_eq!(evict, vec![ring.path_for(10)]);
        // overwriting an existing tag neither duplicates nor evicts
        let (_, evict) = ring.record(40);
        assert!(evict.is_empty());
        assert_eq!(ring.entries.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_thread_lands_atomic_checkpoints() {
        let dir = tmpdir("writer");
        let writer = CkptWriter::spawn();
        let state = TrainState {
            params: vec![vec![1.0, 2.0]],
            gsum: vec![vec![0.0, 0.0]],
            bn: vec![],
            step: 5,
        };
        let bytes = checkpoint::encode(&state, b"aux");
        let path = dir.join("ckpt_000000000005.adpt");
        writer.write(bytes, path.clone(), Vec::new());
        assert!(writer.sync().is_empty());
        let ck = checkpoint::load_full(&path).unwrap();
        assert_eq!(ck.aux, b"aux");
        assert_eq!(ck.state.step, 5);
        drop(writer);
        std::fs::remove_dir_all(&dir).ok();
    }
}
