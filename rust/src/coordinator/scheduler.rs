//! Learning-rate schedules. The paper trains with reduce-on-plateau (ROP,
//! sec. 4.1: "reduce learning rate by a given factor if loss has not
//! decreased for a given number of epochs"); step decay and cosine are
//! provided for the hp-search harness.

use anyhow::{bail, Result};

use crate::util::blob::{BlobReader, BlobWriter};

/// Scheduler state machine; `on_epoch(loss)` returns the lr for the next
/// epoch.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant {
        lr: f32,
    },
    /// The paper's ROP: multiply by `factor` after `patience` epochs without
    /// an improvement larger than `threshold` (relative), floored at
    /// `min_lr`.
    ReduceOnPlateau {
        lr: f32,
        factor: f32,
        patience: u32,
        threshold: f32,
        min_lr: f32,
        best: f32,
        bad_epochs: u32,
    },
    /// lr * gamma every `every` epochs.
    StepDecay {
        lr0: f32,
        gamma: f32,
        every: u32,
        epoch: u32,
    },
    /// Half-cosine from lr0 to min_lr over `total` epochs.
    Cosine {
        lr0: f32,
        min_lr: f32,
        total: u32,
        epoch: u32,
    },
}

impl LrSchedule {
    /// The paper's configuration knobs with common defaults.
    pub fn rop(lr: f32, factor: f32, patience: u32, threshold: f32) -> Self {
        LrSchedule::ReduceOnPlateau {
            lr,
            factor,
            patience,
            threshold,
            min_lr: lr * 1e-3,
            best: f32::INFINITY,
            bad_epochs: 0,
        }
    }

    pub fn current(&self) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::ReduceOnPlateau { lr, .. } => *lr,
            LrSchedule::StepDecay {
                lr0,
                gamma,
                every,
                epoch,
            } => lr0 * gamma.powi((*epoch / *every.max(&1)) as i32),
            LrSchedule::Cosine {
                lr0,
                min_lr,
                total,
                epoch,
            } => {
                let t = (*epoch as f32 / (*total).max(1) as f32).min(1.0);
                min_lr + 0.5 * (lr0 - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// Advance one epoch with its mean training loss; returns the lr to use
    /// for the NEXT epoch.
    pub fn on_epoch(&mut self, epoch_loss: f32) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::ReduceOnPlateau {
                lr,
                factor,
                patience,
                threshold,
                min_lr,
                best,
                bad_epochs,
            } => {
                if epoch_loss.is_finite() && epoch_loss < *best * (1.0 - *threshold) {
                    *best = epoch_loss;
                    *bad_epochs = 0;
                } else {
                    *bad_epochs += 1;
                    if *bad_epochs > *patience {
                        *lr = (*lr * *factor).max(*min_lr);
                        *bad_epochs = 0;
                    }
                }
                *lr
            }
            LrSchedule::StepDecay { epoch, .. } => {
                *epoch += 1;
                self.current()
            }
            LrSchedule::Cosine { epoch, .. } => {
                *epoch += 1;
                self.current()
            }
        }
    }

    /// Serialize the full scheduler state for checkpointing. Floats travel
    /// as raw bits so ROP's `best`/`lr` resume exactly (a decimal round
    /// trip would perturb the plateau comparisons).
    pub fn save_state(&self, w: &mut BlobWriter) {
        match self {
            LrSchedule::Constant { lr } => {
                w.u8(0);
                w.f32_bits(*lr);
            }
            LrSchedule::ReduceOnPlateau {
                lr,
                factor,
                patience,
                threshold,
                min_lr,
                best,
                bad_epochs,
            } => {
                w.u8(1);
                w.f32_bits(*lr);
                w.f32_bits(*factor);
                w.u32(*patience);
                w.f32_bits(*threshold);
                w.f32_bits(*min_lr);
                w.f32_bits(*best);
                w.u32(*bad_epochs);
            }
            LrSchedule::StepDecay {
                lr0,
                gamma,
                every,
                epoch,
            } => {
                w.u8(2);
                w.f32_bits(*lr0);
                w.f32_bits(*gamma);
                w.u32(*every);
                w.u32(*epoch);
            }
            LrSchedule::Cosine {
                lr0,
                min_lr,
                total,
                epoch,
            } => {
                w.u8(3);
                w.f32_bits(*lr0);
                w.f32_bits(*min_lr);
                w.u32(*total);
                w.u32(*epoch);
            }
        }
    }

    /// Inverse of [`save_state`](Self::save_state).
    pub fn load_state(r: &mut BlobReader<'_>) -> Result<LrSchedule> {
        Ok(match r.u8()? {
            0 => LrSchedule::Constant { lr: r.f32_bits()? },
            1 => LrSchedule::ReduceOnPlateau {
                lr: r.f32_bits()?,
                factor: r.f32_bits()?,
                patience: r.u32()?,
                threshold: r.f32_bits()?,
                min_lr: r.f32_bits()?,
                best: r.f32_bits()?,
                bad_epochs: r.u32()?,
            },
            2 => LrSchedule::StepDecay {
                lr0: r.f32_bits()?,
                gamma: r.f32_bits()?,
                every: r.u32()?,
                epoch: r.u32()?,
            },
            3 => LrSchedule::Cosine {
                lr0: r.f32_bits()?,
                min_lr: r.f32_bits()?,
                total: r.u32()?,
                epoch: r.u32()?,
            },
            t => bail!("unknown LrSchedule tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rop_reduces_after_plateau() {
        let mut s = LrSchedule::rop(0.1, 0.5, 2, 1e-3);
        // improving: lr stays
        for l in [1.0f32, 0.9, 0.8] {
            assert_eq!(s.on_epoch(l), 0.1);
        }
        // plateau: patience 2 -> reduced on the 3rd bad epoch
        assert_eq!(s.on_epoch(0.8), 0.1);
        assert_eq!(s.on_epoch(0.8), 0.1);
        assert_eq!(s.on_epoch(0.8), 0.05);
    }

    #[test]
    fn rop_floors_at_min_lr() {
        let mut s = LrSchedule::rop(0.1, 0.1, 0, 1e-3);
        let mut lr = 0.1;
        for _ in 0..10 {
            lr = s.on_epoch(1.0);
        }
        assert!((lr - 1e-4).abs() < 1e-9, "{lr}");
    }

    #[test]
    fn rop_resets_counter_on_improvement() {
        let mut s = LrSchedule::rop(0.1, 0.5, 2, 1e-3);
        s.on_epoch(1.0);
        s.on_epoch(1.0); // bad 1 (first set best)
        s.on_epoch(0.5); // improvement resets
        s.on_epoch(0.5);
        s.on_epoch(0.5);
        assert_eq!(s.current(), 0.1, "not reduced yet after reset");
    }

    #[test]
    fn nan_loss_counts_as_bad_epoch() {
        let mut s = LrSchedule::rop(0.1, 0.5, 0, 1e-3);
        let lr = s.on_epoch(f32::NAN);
        assert_eq!(lr, 0.05);
    }

    /// The resume contract: a mid-run ROP snapshot must restore `best`,
    /// `bad_epochs` and `lr` exactly, so the restored scheduler makes the
    /// same reduce decisions on the same future losses, bit for bit.
    #[test]
    fn rop_snapshot_restore_round_trip_is_exact() {
        let mut a = LrSchedule::rop(0.1, 0.5, 2, 1e-3);
        // drive into a mid-plateau state: best set, bad_epochs == 1
        a.on_epoch(1.0);
        a.on_epoch(0.9);
        a.on_epoch(0.9);

        let mut w = BlobWriter::new();
        a.save_state(&mut w);
        let buf = w.into_vec();
        let mut r = BlobReader::new(&buf);
        let mut b = LrSchedule::load_state(&mut r).unwrap();
        assert!(r.is_empty(), "blob fully consumed");

        // internal state restored exactly
        match (&a, &b) {
            (
                LrSchedule::ReduceOnPlateau { lr, best, bad_epochs, .. },
                LrSchedule::ReduceOnPlateau { lr: lr2, best: best2, bad_epochs: bad2, .. },
            ) => {
                assert_eq!(lr.to_bits(), lr2.to_bits());
                assert_eq!(best.to_bits(), best2.to_bits());
                assert_eq!(bad_epochs, bad2);
            }
            _ => panic!("variant changed across round trip"),
        }
        // and future decisions agree bit for bit, including the reduce edge
        for l in [0.9f32, 0.9, 0.9, 0.85, f32::NAN, 0.2] {
            assert_eq!(a.on_epoch(l).to_bits(), b.on_epoch(l).to_bits());
        }
    }

    #[test]
    fn all_variants_round_trip() {
        let scheds = [
            LrSchedule::Constant { lr: 0.025 },
            LrSchedule::StepDecay { lr0: 1.0, gamma: 0.1, every: 2, epoch: 3 },
            LrSchedule::Cosine { lr0: 1.0, min_lr: 0.01, total: 10, epoch: 7 },
        ];
        for s in scheds {
            let mut w = BlobWriter::new();
            s.save_state(&mut w);
            let buf = w.into_vec();
            let back = LrSchedule::load_state(&mut BlobReader::new(&buf)).unwrap();
            assert_eq!(s.current().to_bits(), back.current().to_bits());
        }
    }

    #[test]
    fn step_decay() {
        let mut s = LrSchedule::StepDecay {
            lr0: 1.0,
            gamma: 0.1,
            every: 2,
            epoch: 0,
        };
        assert_eq!(s.current(), 1.0);
        s.on_epoch(1.0);
        assert_eq!(s.current(), 1.0);
        s.on_epoch(1.0);
        assert!((s.current() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cosine_monotone_to_floor() {
        let mut s = LrSchedule::Cosine {
            lr0: 1.0,
            min_lr: 0.01,
            total: 10,
            epoch: 0,
        };
        let mut prev = s.current();
        for _ in 0..12 {
            let lr = s.on_epoch(1.0);
            assert!(lr <= prev + 1e-6);
            prev = lr;
        }
        assert!((prev - 0.01).abs() < 1e-6);
    }
}
