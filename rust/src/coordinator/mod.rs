//! The L3 training coordinator: AdaPT-SGD (alg. 1) driving the compiled L2
//! train-step through PJRT, with the precision policy fully host-side.

pub mod checkpoint;
pub mod scheduler;
pub mod trainer;

pub use scheduler::LrSchedule;
pub use trainer::{
    train, train_via_model, train_with_data, Policy, ServableModel, TrainConfig, TrainOutcome,
};
