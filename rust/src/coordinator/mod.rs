//! The L3 training coordinator: AdaPT-SGD (alg. 1) driving the compiled L2
//! train-step through PJRT, with the precision policy fully host-side.
//! `supervisor` wraps the same loop with full-state checkpoints, divergence
//! rollback and deterministic fault injection.

pub mod checkpoint;
pub mod faults;
pub mod scheduler;
pub mod supervisor;
pub mod trainer;

pub use faults::{CkptFault, FaultKind, FaultPlan};
pub use scheduler::LrSchedule;
pub use supervisor::{
    supervise, supervise_via_model, RunAborted, SupervisedOutcome, SupervisorConfig,
    SupervisorError,
};
pub use trainer::{
    train, train_via_model, train_with_data, Policy, ServableModel, TrainConfig, TrainOutcome,
};
