//! The L3 training coordinator: AdaPT-SGD (alg. 1) driving the compiled L2
//! train-step through PJRT, with the precision policy fully host-side.
//! `supervisor` wraps the same loop with full-state checkpoints, divergence
//! rollback and deterministic fault injection.

pub mod checkpoint;
pub mod faults;
pub mod scheduler;
pub mod supervisor;
pub mod trainer;

pub use faults::{CkptFault, FaultKind, FaultPlan};
pub use scheduler::LrSchedule;
pub use supervisor::{
    supervise, supervise_via_model, supervise_via_model_telemetry, supervise_with_telemetry,
    RunAborted, SupervisedOutcome, SupervisorConfig, SupervisorError,
};
pub use trainer::{
    train, train_via_model, train_via_model_telemetry, train_with_data,
    train_with_data_telemetry, Policy, ServableModel, TrainConfig, TrainOutcome,
};
