//! Checkpointing: binary snapshots of the full training state (master
//! weights, gradient accumulators, BN stats, step counter) so long runs
//! survive interruption and poisoned steps can be rolled back.
//!
//! Format (little-endian, versioned):
//!   magic "ADPT" | u32 version | u64 step | u32 n_sections
//!   per section: u32 n_tensors, per tensor: u64 len, f32 data...
//! Sections are (params, gsum, bn). A trailing CRC-like xor checksum guards
//! against truncation (no external hashing crates offline).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::TrainState;

const MAGIC: &[u8; 4] = b"ADPT";
const VERSION: u32 = 1;

fn xor_checksum(data: &[f32]) -> u64 {
    let mut acc = 0xA5A5_5A5A_DEAD_BEEFu64;
    for (i, &v) in data.iter().enumerate() {
        acc ^= (v.to_bits() as u64).rotate_left((i % 61) as u32);
    }
    acc
}

fn write_section<W: Write>(w: &mut W, tensors: &[Vec<f32>], sum: &mut u64) -> Result<()> {
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        w.write_all(&(t.len() as u64).to_le_bytes())?;
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(t.as_ptr() as *const u8, t.len() * 4) };
        w.write_all(bytes)?;
        *sum ^= xor_checksum(t);
    }
    Ok(())
}

fn read_section<R: Read>(r: &mut R, sum: &mut u64) -> Result<Vec<Vec<f32>>> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4) as usize;
    if n > 1_000_000 {
        return Err(anyhow!("implausible tensor count {n}"));
    }
    let mut out = Vec::with_capacity(n);
    let mut b8 = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        if len > 1 << 30 {
            return Err(anyhow!("implausible tensor len {len}"));
        }
        let mut t = vec![0f32; len];
        let bytes: &mut [u8] =
            unsafe { std::slice::from_raw_parts_mut(t.as_mut_ptr() as *mut u8, len * 4) };
        r.read_exact(bytes)?;
        *sum ^= xor_checksum(&t);
        out.push(t);
    }
    Ok(out)
}

/// Write a checkpoint atomically (tmp + rename).
pub fn save(state: &TrainState, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&state.step.to_le_bytes())?;
        f.write_all(&3u32.to_le_bytes())?;
        let mut sum = 0u64;
        write_section(&mut f, &state.params, &mut sum)?;
        write_section(&mut f, &state.gsum, &mut sum)?;
        write_section(&mut f, &state.bn, &mut sum)?;
        f.write_all(&sum.to_le_bytes())?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename to {}", path.display()))?;
    Ok(())
}

/// Load a checkpoint, verifying magic/version/checksum.
pub fn load(path: &Path) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("bad magic {:?}", magic));
    }
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        return Err(anyhow!("unsupported checkpoint version {version}"));
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    f.read_exact(&mut b4)?;
    let n_sections = u32::from_le_bytes(b4);
    if n_sections != 3 {
        return Err(anyhow!("expected 3 sections, got {n_sections}"));
    }
    let mut sum = 0u64;
    let params = read_section(&mut f, &mut sum)?;
    let gsum = read_section(&mut f, &mut sum)?;
    let bn = read_section(&mut f, &mut sum)?;
    f.read_exact(&mut b8)?;
    let want = u64::from_le_bytes(b8);
    if want != sum {
        return Err(anyhow!("checksum mismatch: file corrupt/truncated"));
    }
    Ok(TrainState {
        params,
        gsum,
        bn,
        step,
    })
}

/// Verify a checkpoint matches a manifest's shapes (guards against loading
/// a checkpoint into the wrong artifact).
pub fn validate_against(state: &TrainState, man: &crate::runtime::Manifest) -> Result<()> {
    if state.params.len() != man.params.len() {
        return Err(anyhow!(
            "param count {} != manifest {}",
            state.params.len(),
            man.params.len()
        ));
    }
    for (t, spec) in state.params.iter().zip(&man.params) {
        if t.len() != spec.elems() {
            return Err(anyhow!(
                "param {}: {} elems != manifest {}",
                spec.name,
                t.len(),
                spec.elems()
            ));
        }
    }
    let l = man.num_layers;
    if state.gsum.len() != l {
        return Err(anyhow!("gsum count {} != L {l}", state.gsum.len()));
    }
    if state.bn.len() != man.bn_state.len() {
        return Err(anyhow!("bn count mismatch"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> TrainState {
        TrainState {
            params: vec![vec![1.0, -2.5, 3.25], vec![0.0; 7]],
            gsum: vec![vec![0.5; 3]],
            bn: vec![vec![0.0; 4], vec![1.0; 4]],
            step: 1234,
        }
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adapt_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn round_trip() {
        let s = sample_state();
        let p = tmpfile("rt");
        save(&s, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.step, 1234);
        assert_eq!(back.params, s.params);
        assert_eq!(back.gsum, s.gsum);
        assert_eq!(back.bn, s.bn);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let s = sample_state();
        let p = tmpfile("trunc");
        save(&s, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let s = sample_state();
        let p = tmpfile("corrupt");
        save(&s, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err(), "flipped byte must fail the checksum");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("magic");
        std::fs::write(&p, b"NOPE12345678").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn nan_preserved_bitexact() {
        // snapshots of poisoned states must round-trip NaN payloads
        let mut s = sample_state();
        s.params[0][0] = f32::NAN;
        s.params[0][1] = f32::NEG_INFINITY;
        let p = tmpfile("nan");
        save(&s, &p).unwrap();
        let back = load(&p).unwrap();
        assert!(back.params[0][0].is_nan());
        assert_eq!(back.params[0][1], f32::NEG_INFINITY);
        std::fs::remove_file(&p).ok();
    }
}
