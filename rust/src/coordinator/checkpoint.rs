//! Checkpointing: binary snapshots of the full training state so long runs
//! survive interruption and poisoned steps can be rolled back.
//!
//! v2 format (little-endian):
//!
//! ```text
//! offset 0   magic "ADPT"
//! offset 4   u32 version = 2
//! offset 8   u64 body_len          (size of everything before the checksum)
//! offset 16  u64 step              (TrainState::step)
//! offset 24  u32 n_sections = 4
//!            section params | gsum | bn:
//!              u32 n_tensors; per tensor: u64 elems, raw f32 LE bits
//!            section aux: u64 byte_len, raw bytes (supervisor blob;
//!              empty when saved via `save`)
//! offset body_len   u64 FNV-1a checksum of bytes[0..body_len]
//! ```
//!
//! The explicit `body_len` header pins both integrity checks to fixed byte
//! ranges *before* any structural parsing, which is what makes the fuzz
//! guarantees deterministic: any truncation changes the length equation,
//! any appended garbage is `TrailingGarbage`, and any single bit flip in
//! the body lands inside the checksummed range (FNV-1a's per-byte
//! xor-multiply chain is a bijection of the accumulator, so a flipped byte
//! can never cancel out). v1 files (xor-of-f32-bits checksum, no aux
//! section) remain readable. Writes are atomic (tmp + rename).
//!
//! The `aux` section is opaque bytes at this layer; `coordinator::
//! supervisor` packs the full AdaPT run state into it (controller formats
//! and PushUp windows, data-order RNG, scheduler state, epoch/step cursors,
//! the `RunRecord` prefix) so a resumed run is bit-identical to an
//! uninterrupted one.

use std::fmt;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::TrainState;
use crate::util::blob::BlobReader;

const MAGIC: &[u8; 4] = b"ADPT";
/// Current write-side format version.
pub const VERSION: u32 = 2;
/// Fixed v2 header size: magic + version + body_len + step + n_sections.
const V2_HEADER: usize = 4 + 4 + 8 + 8 + 4;

/// Typed load/save failures, so callers (and tests) can distinguish "newer
/// format than this binary" from genuine corruption.
#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    /// Valid magic but a version this binary does not know how to parse.
    FutureVersion { found: u32, supported: u32 },
    /// Structurally complete checkpoint followed by extra bytes.
    TrailingGarbage { extra: u64 },
    /// Truncation, checksum mismatch, or implausible structure.
    Corrupt(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io: {e}"),
            CheckpointError::BadMagic(m) => write!(f, "bad checkpoint magic {m:?}"),
            CheckpointError::FutureVersion { found, supported } => {
                write!(f, "checkpoint version {found} is newer than supported {supported}")
            }
            CheckpointError::TrailingGarbage { extra } => {
                write!(f, "{extra} trailing bytes after checkpoint checksum")
            }
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// A fully parsed checkpoint: tensor state plus the opaque aux blob.
#[derive(Debug)]
pub struct Checkpoint {
    pub state: TrainState,
    /// Supervisor-owned run state; empty for v1 files and plain `save`s.
    pub aux: Vec<u8>,
    pub version: u32,
}

/// FNV-1a over raw bytes. Every step is `acc = (acc ^ b) * prime` — a
/// bijection of `acc` for fixed input — so any single corrupted byte in
/// the hashed range is guaranteed to change the final value.
fn byte_checksum(data: &[u8]) -> u64 {
    let mut acc = 0xCBF2_9CE4_8422_2325u64;
    for &b in data {
        acc ^= b as u64;
        acc = acc.wrapping_mul(0x0000_0100_0000_01B3);
    }
    acc
}

/// v1's checksum: xor of rotated f32 bit patterns, tensor data only.
fn xor_checksum(data: &[f32]) -> u64 {
    let mut acc = 0xA5A5_5A5A_DEAD_BEEFu64;
    for (i, &v) in data.iter().enumerate() {
        acc ^= (v.to_bits() as u64).rotate_left((i % 61) as u32);
    }
    acc
}

fn tensor_bytes(t: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(t.as_ptr() as *const u8, t.len() * 4) }
}

fn push_section(out: &mut Vec<u8>, tensors: &[Vec<f32>]) {
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        out.extend_from_slice(&(t.len() as u64).to_le_bytes());
        out.extend_from_slice(tensor_bytes(t));
    }
}

/// Serialize a complete v2 checkpoint image (header + sections + checksum).
/// Pure in-memory: the supervisor calls this on the hot path and hands the
/// buffer to its background writer thread.
pub fn encode(state: &TrainState, aux: &[u8]) -> Vec<u8> {
    let tensor_elems: usize = state
        .params
        .iter()
        .chain(&state.gsum)
        .chain(&state.bn)
        .map(Vec::len)
        .sum();
    let n_tensors = state.params.len() + state.gsum.len() + state.bn.len();
    let body_len = V2_HEADER + 3 * 4 + n_tensors * 8 + tensor_elems * 4 + 8 + aux.len();
    let mut out = Vec::with_capacity(body_len + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(body_len as u64).to_le_bytes());
    out.extend_from_slice(&state.step.to_le_bytes());
    out.extend_from_slice(&4u32.to_le_bytes());
    push_section(&mut out, &state.params);
    push_section(&mut out, &state.gsum);
    push_section(&mut out, &state.bn);
    out.extend_from_slice(&(aux.len() as u64).to_le_bytes());
    out.extend_from_slice(aux);
    debug_assert_eq!(out.len(), body_len);
    let sum = byte_checksum(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Write a pre-serialized checkpoint image atomically (tmp + rename).
pub fn write_atomic(bytes: &[u8], path: &Path) -> Result<(), CheckpointError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Write a v2 checkpoint with an aux blob, atomically.
pub fn save_with_aux(state: &TrainState, aux: &[u8], path: &Path) -> Result<(), CheckpointError> {
    write_atomic(&encode(state, aux), path)
}

/// Write a checkpoint atomically (tmp + rename). Tensor state only; the
/// supervisor uses [`save_with_aux`] to carry the full run state.
pub fn save(state: &TrainState, path: &Path) -> Result<(), CheckpointError> {
    save_with_aux(state, &[], path)
}

/// Write a legacy v1 checkpoint. Kept so back-compat reads stay testable.
pub fn save_v1(state: &TrainState, path: &Path) -> Result<(), CheckpointError> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&state.step.to_le_bytes());
    out.extend_from_slice(&3u32.to_le_bytes());
    let mut sum = 0u64;
    for sec in [&state.params, &state.gsum, &state.bn] {
        push_section(&mut out, sec);
        for t in sec.iter() {
            sum ^= xor_checksum(t);
        }
    }
    out.extend_from_slice(&sum.to_le_bytes());
    write_atomic(&out, path)
}

fn corrupt(e: anyhow::Error) -> CheckpointError {
    CheckpointError::Corrupt(e.to_string())
}

fn read_tensors(r: &mut BlobReader<'_>) -> Result<Vec<Vec<f32>>> {
    let n = r.u32()? as usize;
    if n > 1_000_000 {
        return Err(anyhow!("implausible tensor count {n}"));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = r.u64()? as usize;
        if len > 1 << 30 {
            return Err(anyhow!("implausible tensor len {len}"));
        }
        let bytes = r.take(len * 4)?;
        let mut t = Vec::with_capacity(len);
        for c in bytes.chunks_exact(4) {
            t.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.push(t);
    }
    Ok(out)
}

fn load_v2(bytes: &[u8]) -> Result<Checkpoint, CheckpointError> {
    if bytes.len() < V2_HEADER + 8 {
        return Err(CheckpointError::Corrupt(format!(
            "{} bytes is too short for a v2 checkpoint",
            bytes.len()
        )));
    }
    let body_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let file_len = bytes.len() as u64;
    // integrity first, against fixed ranges derived from the header alone
    if body_len < (V2_HEADER as u64) || body_len + 8 > file_len {
        return Err(CheckpointError::Corrupt(format!(
            "body length {body_len} inconsistent with file length {file_len} (truncated?)"
        )));
    }
    if body_len + 8 < file_len {
        return Err(CheckpointError::TrailingGarbage { extra: file_len - (body_len + 8) });
    }
    let body = &bytes[..body_len as usize];
    let want = u64::from_le_bytes(bytes[body_len as usize..].try_into().unwrap());
    if byte_checksum(body) != want {
        return Err(CheckpointError::Corrupt("checksum mismatch".into()));
    }
    // the body is now known intact; structural parse cannot mis-frame
    let mut r = BlobReader::new(&body[16..]); // past magic/version/body_len
    let parse = |r: &mut BlobReader<'_>| -> Result<Checkpoint> {
        let step = r.u64()?;
        let n_sections = r.u32()?;
        if n_sections != 4 {
            return Err(anyhow!("expected 4 sections, got {n_sections}"));
        }
        let params = read_tensors(r)?;
        let gsum = read_tensors(r)?;
        let bn = read_tensors(r)?;
        let aux_len = r.u64()? as usize;
        if aux_len != r.remaining() {
            return Err(anyhow!(
                "aux length {aux_len} != {} remaining body bytes",
                r.remaining()
            ));
        }
        let aux = r.take(aux_len)?.to_vec();
        Ok(Checkpoint {
            state: TrainState { params, gsum, bn, step },
            aux,
            version: 2,
        })
    };
    parse(&mut r).map_err(corrupt)
}

fn load_v1(bytes: &[u8]) -> Result<Checkpoint, CheckpointError> {
    let mut r = BlobReader::new(&bytes[8..]); // past magic/version
    let parse = |r: &mut BlobReader<'_>| -> Result<(TrainState, u64)> {
        let step = r.u64()?;
        let n_sections = r.u32()?;
        if n_sections != 3 {
            return Err(anyhow!("expected 3 sections, got {n_sections}"));
        }
        let params = read_tensors(r)?;
        let gsum = read_tensors(r)?;
        let bn = read_tensors(r)?;
        let want = r.u64()?;
        Ok((TrainState { params, gsum, bn, step }, want))
    };
    let (state, want) = parse(&mut r).map_err(corrupt)?;
    if !r.is_empty() {
        return Err(CheckpointError::TrailingGarbage { extra: r.remaining() as u64 });
    }
    let mut sum = 0u64;
    for sec in [&state.params, &state.gsum, &state.bn] {
        for t in sec.iter() {
            sum ^= xor_checksum(t);
        }
    }
    if sum != want {
        return Err(CheckpointError::Corrupt("v1 checksum mismatch".into()));
    }
    Ok(Checkpoint { state, aux: Vec::new(), version: 1 })
}

/// Load and fully verify a checkpoint (v1 or v2), returning the aux blob.
pub fn load_full(path: &Path) -> Result<Checkpoint, CheckpointError> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < 8 {
        return Err(CheckpointError::Corrupt(format!("{} bytes is not a checkpoint", bytes.len())));
    }
    let magic: [u8; 4] = bytes[..4].try_into().unwrap();
    if &magic != MAGIC {
        return Err(CheckpointError::BadMagic(magic));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    match version {
        1 => load_v1(&bytes),
        2 => load_v2(&bytes),
        v => Err(CheckpointError::FutureVersion { found: v, supported: VERSION }),
    }
}

/// Load a checkpoint, verifying magic/version/checksum.
pub fn load(path: &Path) -> Result<TrainState, CheckpointError> {
    Ok(load_full(path)?.state)
}

/// Verify a checkpoint matches a manifest's shapes (guards against loading
/// a checkpoint into the wrong artifact).
pub fn validate_against(state: &TrainState, man: &crate::runtime::Manifest) -> Result<()> {
    if state.params.len() != man.params.len() {
        return Err(anyhow!(
            "param count {} != manifest {}",
            state.params.len(),
            man.params.len()
        ));
    }
    for (t, spec) in state.params.iter().zip(&man.params) {
        if t.len() != spec.elems() {
            return Err(anyhow!(
                "param {}: {} elems != manifest {}",
                spec.name,
                t.len(),
                spec.elems()
            ));
        }
    }
    let l = man.num_layers;
    if state.gsum.len() != l {
        return Err(anyhow!("gsum count {} != L {l}", state.gsum.len()));
    }
    if state.bn.len() != man.bn_state.len() {
        return Err(anyhow!("bn count mismatch"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> TrainState {
        TrainState {
            params: vec![vec![1.0, -2.5, 3.25], vec![0.0; 7]],
            gsum: vec![vec![0.5; 3]],
            bn: vec![vec![0.0; 4], vec![1.0; 4]],
            step: 1234,
        }
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adapt_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn round_trip() {
        let s = sample_state();
        let p = tmpfile("rt");
        save(&s, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.step, 1234);
        assert_eq!(back.params, s.params);
        assert_eq!(back.gsum, s.gsum);
        assert_eq!(back.bn, s.bn);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn aux_round_trip() {
        let s = sample_state();
        let p = tmpfile("aux");
        let aux: Vec<u8> = (0..=255).collect();
        save_with_aux(&s, &aux, &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.version, 2);
        assert_eq!(ck.aux, aux);
        assert_eq!(ck.state.params, s.params);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let s = sample_state();
        let p = tmpfile("trunc");
        save(&s, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(matches!(load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let s = sample_state();
        let p = tmpfile("corrupt");
        save(&s, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err(), "flipped byte must fail the checksum");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("magic");
        std::fs::write(&p, b"NOPE12345678").unwrap();
        assert!(matches!(load(&p), Err(CheckpointError::BadMagic(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_future_version_typed() {
        let s = sample_state();
        let p = tmpfile("future");
        save(&s, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        match load(&p) {
            Err(CheckpointError::FutureVersion { found, supported }) => {
                assert_eq!(found, VERSION + 1);
                assert_eq!(supported, VERSION);
            }
            other => panic!("want FutureVersion, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_trailing_garbage_typed() {
        let s = sample_state();
        let p = tmpfile("trail");
        save(&s, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(b"junk!");
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            load(&p),
            Err(CheckpointError::TrailingGarbage { extra: 5 })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reads_legacy_v1_files() {
        let s = sample_state();
        let p = tmpfile("v1");
        save_v1(&s, &p).unwrap();
        let ck = load_full(&p).unwrap();
        assert_eq!(ck.version, 1);
        assert!(ck.aux.is_empty());
        assert_eq!(ck.state.params, s.params);
        assert_eq!(ck.state.step, s.step);
        // v1 trailing garbage is rejected too
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            load(&p),
            Err(CheckpointError::TrailingGarbage { extra: 1 })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn nan_preserved_bitexact() {
        // snapshots of poisoned states must round-trip NaN payloads
        let mut s = sample_state();
        s.params[0][0] = f32::NAN;
        s.params[0][1] = f32::NEG_INFINITY;
        let p = tmpfile("nan");
        save(&s, &p).unwrap();
        let back = load(&p).unwrap();
        assert!(back.params[0][0].is_nan());
        assert_eq!(back.params[0][1], f32::NEG_INFINITY);
        std::fs::remove_file(&p).ok();
    }
}
