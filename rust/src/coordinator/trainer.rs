//! The ASGD training loop (alg. 1): batches through the PJRT executable,
//! precision switching between steps, periodic quantized evaluation,
//! full metric recording. Batch assembly is prefetched on a side thread.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::{Dataset, PrefetchLoader, SyntheticVision};
use crate::init::{self, Initializer};
use crate::metrics::{RunRecord, StepRow, SwitchEventLite};
use crate::muppet::{MuppetController, MuppetHyper};
use crate::quant::qmap::SwitchEvent;
use crate::quant::{AdaptController, Float32Controller, QuantController, QuantHyper, QuantPool};
use crate::runtime::{Engine, Hyper, LoadedModel, TrainState};
use crate::telemetry::{spans, Event, TelemetrySink};

use super::scheduler::LrSchedule;

/// Which precision policy drives the run.
#[derive(Debug, Clone)]
pub enum Policy {
    Adapt(QuantHyper),
    Muppet(MuppetHyper),
    Float32,
}

impl Policy {
    pub fn mode_name(&self) -> &'static str {
        match self {
            Policy::Adapt(_) => "adapt",
            Policy::Muppet(_) => "muppet",
            Policy::Float32 => "float32",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact name, e.g. "resnet20-c100".
    pub artifact: String,
    pub policy: Policy,
    pub epochs: usize,
    /// Training-set size (synthetic datasets are generated to this size).
    pub train_size: usize,
    /// Held-out evaluation-set size.
    pub eval_size: usize,
    pub hyper: Hyper,
    pub seed: u64,
    pub init: Initializer,
    /// TNVS empirical scaling factor s (sec. 3.1).
    pub init_scale: f64,
    /// Evaluate every n epochs (and always at the end).
    pub eval_every: usize,
    /// Gradient accumulation steps — perf-model input only (the compiled
    /// step applies each batch directly; accs scales eq. 8/9 as in §4.1.2).
    pub accs: u32,
    /// Print a progress line every n steps (0 = silent).
    pub log_every: usize,
    /// Learning-rate schedule; None = constant `hyper.lr`. The paper trains
    /// with reduce-on-plateau (sec. 4.1).
    pub lr_schedule: Option<LrSchedule>,
}

impl TrainConfig {
    /// Fast profile sized for the single-core CPU testbed.
    pub fn fast(artifact: &str, policy: Policy) -> Self {
        TrainConfig {
            artifact: artifact.to_string(),
            policy,
            epochs: 6,
            train_size: 1024,
            eval_size: 256,
            hyper: Hyper::default(),
            seed: 42,
            init: Initializer::Tnvs,
            init_scale: 1.0,
            eval_every: 2,
            accs: 1,
            log_every: 0,
            lr_schedule: Some(LrSchedule::rop(0.05, 0.5, 1, 1e-3)),
        }
    }

    /// The paper's full profile (sec. 4.1): 100 epochs, batch 512 — only
    /// practical on real hardware; kept for completeness/documentation.
    pub fn paper(artifact: &str, policy: Policy) -> Self {
        TrainConfig {
            artifact: artifact.to_string(),
            policy,
            epochs: 100,
            train_size: 50_000,
            eval_size: 10_000,
            hyper: Hyper::default(),
            seed: 42,
            init: Initializer::Tnvs,
            init_scale: 1.0,
            eval_every: 5,
            accs: 1,
            log_every: 50,
            lr_schedule: Some(LrSchedule::rop(0.05, 0.5, 10, 1e-3)),
        }
    }
}

pub struct TrainOutcome {
    pub record: RunRecord,
    pub state: TrainState,
    pub final_qparams: Vec<f32>,
    pub final_wordlengths: Vec<u8>,
}

/// A self-contained export of a finished run — everything the serving
/// registry needs to freeze and publish the model
/// ([`ServedModel::from_servable`](crate::serve::ServedModel::from_servable)):
/// the manifest, the trained float32 master weights and the final runtime
/// qparams tensor (whose weight rows pin the deployed `<WL, FL>` formats).
#[derive(Debug, Clone)]
pub struct ServableModel {
    /// Serving name (defaults to the run's artifact name).
    pub name: String,
    pub manifest: crate::runtime::Manifest,
    /// Full manifest parameter stream (kernel+bias, or kernel+gamma+beta
    /// for batchnorm layers), trained.
    pub params: Vec<Vec<f32>>,
    /// Running batchnorm (mean, var) tensors at the end of the run (empty
    /// for BN-free models).
    pub bn: Vec<Vec<f32>>,
    /// The `[2L, 5]` runtime qparams tensor at the end of the run.
    pub qparams: Vec<f32>,
    /// Final per-layer word lengths (reporting/size accounting).
    pub wordlengths: Vec<u8>,
}

impl TrainOutcome {
    /// Export this outcome for serving. `manifest` must be the manifest the
    /// run trained against (the trainer never owns it — callers hold the
    /// [`LoadedModel`]).
    pub fn servable(&self, manifest: &crate::runtime::Manifest) -> ServableModel {
        ServableModel {
            name: self.record.name.clone(),
            manifest: manifest.clone(),
            params: self.state.params.clone(),
            bn: self.state.bn.clone(),
            qparams: self.final_qparams.clone(),
            wordlengths: self.final_wordlengths.clone(),
        }
    }
}

/// Pick train + held-out datasets matching the artifact's input signature.
/// The held-out split shares the task (class templates / files) with the
/// train split but uses disjoint samples. Real CIFAR is used when
/// $ADAPT_DATA contains the binaries; otherwise the synthetic substitute
/// (DESIGN.md #Substitutions).
pub(crate) fn datasets_for(
    man: &crate::runtime::Manifest,
    train_len: usize,
    eval_len: usize,
    seed: u64,
) -> Result<(Arc<dyn Dataset>, Arc<dyn Dataset>)> {
    let shape = (
        man.input_shape[0],
        man.input_shape[1],
        man.input_shape[2],
    );
    if let Ok(dir) = std::env::var("ADAPT_DATA") {
        let dir = std::path::PathBuf::from(dir);
        if shape == (32, 32, 3) {
            let pair = if man.classes == 10 {
                (
                    crate::data::cifar::CifarDataset::load_cifar10(&dir, true),
                    crate::data::cifar::CifarDataset::load_cifar10(&dir, false),
                )
            } else {
                (
                    crate::data::cifar::CifarDataset::load_cifar100(&dir, true),
                    crate::data::cifar::CifarDataset::load_cifar100(&dir, false),
                )
            };
            if let (Ok(tr), Ok(te)) = pair {
                return Ok((Arc::new(tr), Arc::new(te)));
            }
        }
    }
    let (h, w, c) = shape;
    let noise = if c == 1 { 0.25 } else { 0.35 };
    let train = SyntheticVision::new(h, w, c, man.classes, train_len, seed, noise);
    let eval =
        SyntheticVision::new(h, w, c, man.classes, train_len, seed, noise).heldout(train_len, eval_len);
    Ok((Arc::new(train), Arc::new(eval)))
}

pub(crate) fn make_controller(
    policy: &Policy,
    man: &crate::runtime::Manifest,
    pool: &Option<Arc<QuantPool>>,
) -> Box<dyn QuantController> {
    match policy {
        Policy::Adapt(h) => {
            let pool = pool
                .clone()
                .unwrap_or_else(|| Arc::new(QuantPool::with_default_threads()));
            Box::new(AdaptController::with_pool(man, *h, pool))
        }
        Policy::Muppet(h) => Box::new(MuppetController::new(man, h.clone())),
        Policy::Float32 => Box::new(Float32Controller::new(man)),
    }
}

/// Evaluate quantized top-1 accuracy over the held-out set.
pub(crate) fn evaluate(
    model: &LoadedModel,
    state: &TrainState,
    qparams: &[f32],
    eval: &dyn Dataset,
) -> Result<f32> {
    let b = model.manifest.batch;
    let n_batches = (eval.len() / b).max(1);
    let mut acc = 0.0f32;
    for k in 0..n_batches {
        let batch = eval_batch(eval, b, k);
        acc += model.infer_accuracy(&state.params, &state.bn, &batch.0, &batch.1, qparams)?;
    }
    Ok(acc / n_batches as f32)
}

fn eval_batch(eval: &dyn Dataset, b: usize, k: usize) -> (Vec<f32>, Vec<i32>) {
    let elems = eval.sample_elems();
    let n = eval.len();
    let mut x = vec![0.0f32; b * elems];
    let mut y = vec![0i32; b];
    for j in 0..b {
        let i = (k * b + j) % n;
        y[j] = eval.fill(i, &mut x[j * elems..(j + 1) * elems]);
    }
    (x, y)
}

/// Train with the dataset chosen from the manifest (synthetic or $ADAPT_DATA).
pub fn train(engine: &Engine, dir: &std::path::Path, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let model = engine.load_model(dir, &cfg.artifact)?;
    train_via_model(&model, cfg)
}

/// Train against an already-compiled model (XLA compilation of the larger
/// train steps takes minutes on one core — callers batch several policy
/// runs over one LoadedModel).
pub fn train_via_model(model: &LoadedModel, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let (data, eval) = datasets_for(&model.manifest, cfg.train_size, cfg.eval_size, cfg.seed)?;
    train_with_data(model, cfg, data, eval)
}

/// Core loop, dataset-injected (tests use tiny datasets directly).
pub fn train_with_data(
    model: &LoadedModel,
    cfg: &TrainConfig,
    data: Arc<dyn Dataset>,
    eval: Arc<dyn Dataset>,
) -> Result<TrainOutcome> {
    train_with_data_telemetry(model, cfg, data, eval, &TelemetrySink::disabled())
}

/// [`train_via_model`] with every step/switch/eval mirrored into `sink`
/// (see [`crate::telemetry`]). With a disabled sink this is exactly the
/// plain entry point — all emission is guarded, and the determinism test
/// pins that the trained bits do not depend on the sink.
pub fn train_via_model_telemetry(
    model: &LoadedModel,
    cfg: &TrainConfig,
    sink: &TelemetrySink,
) -> Result<TrainOutcome> {
    let (data, eval) = datasets_for(&model.manifest, cfg.train_size, cfg.eval_size, cfg.seed)?;
    train_with_data_telemetry(model, cfg, data, eval, sink)
}

/// Emit any switch events the controller recorded since the last call,
/// advancing the high-water mark. The pending list survives untouched for
/// the end-of-run [`RunRecord`] drain (and for checkpointing, which is how
/// a rollback rewinds the emitted counter too).
pub(crate) fn emit_new_switches(sink: &TelemetrySink, pending: &[SwitchEvent], emitted: &mut usize) {
    for ev in &pending[(*emitted).min(pending.len())..] {
        sink.emit(&Event::Switch(SwitchEventLite::from(ev)));
    }
    *emitted = pending.len();
}

/// Core loop with a telemetry sink threaded through.
pub fn train_with_data_telemetry(
    model: &LoadedModel,
    cfg: &TrainConfig,
    data: Arc<dyn Dataset>,
    eval: Arc<dyn Dataset>,
    sink: &TelemetrySink,
) -> Result<TrainOutcome> {
    let man = &model.manifest;
    if data.input_shape() != (man.input_shape[0], man.input_shape[1], man.input_shape[2]) {
        return Err(anyhow!("dataset shape mismatch with artifact"));
    }
    let batch = man.batch;
    let steps_per_epoch = (data.len() / batch).max(1);
    // The persistent quantization worker pool the controller shares for
    // on-step window batches, the epoch-boundary re-sync and the PushUp
    // lookback fan-out. When the execution backend owns a team already (the
    // native interpreter fans its matmuls out on one), reuse it instead of
    // spawning a second; otherwise workers spawn once per run, not once per
    // precision switch — and only for policies that actually fan work out
    // (baselines never submit a job, so they get no extra threads).
    let pool: Option<Arc<QuantPool>> = match &cfg.policy {
        Policy::Adapt(_) => Some(
            model
                .pool
                .clone()
                .unwrap_or_else(|| Arc::new(QuantPool::with_default_threads())),
        ),
        _ => None,
    };
    let mut controller = make_controller(&cfg.policy, man, &pool);

    let mut state = TrainState {
        params: init::init_params(man, cfg.init, cfg.init_scale, cfg.seed),
        gsum: init::init_gsum(man),
        bn: init::init_bn(man),
        step: cfg.seed.wrapping_mul(7919) % (1 << 20), // decorrelate PRNG streams
    };

    let loader = PrefetchLoader::spawn(data, batch, cfg.seed ^ 0xBA7C4, 2);
    let t0 = Instant::now();
    let mut hyper = cfg.hyper;
    let mut schedule = cfg.lr_schedule.clone();
    if let Some(sch) = &schedule {
        hyper.lr = sch.current();
    }

    let mut rec = RunRecord {
        name: cfg.artifact.clone(),
        mode: cfg.policy.mode_name().to_string(),
        batch,
        accs: cfg.accs,
        epochs: cfg.epochs,
        steps_per_epoch,
        num_layers: man.num_layers,
        ..Default::default()
    };

    let telemetry = sink.is_enabled();
    if telemetry {
        sink.emit(&Event::RunStart {
            name: rec.name.clone(),
            mode: rec.mode.clone(),
            batch,
            accs: cfg.accs,
            epochs: cfg.epochs,
            steps_per_epoch,
            num_layers: man.num_layers,
        });
    }
    // Timing spans are thread-local and off by default; the native step
    // only pays an Instant read per phase when this run asked for them.
    spans::set_enabled(telemetry);
    let mut emitted_switches = 0usize;

    let mut global_step = 0u64;
    for epoch in 0..cfg.epochs {
        for _ in 0..steps_per_epoch {
            let b = loader.next();
            let qp = controller.qparams();
            let m = model.train_step(&mut state, &b.x, &b.y, &qp, &hyper)?;
            controller.on_step(&mut state, &m);
            global_step += 1;

            rec.steps.push(StepRow {
                loss: m.loss,
                ce: m.ce,
                acc: m.acc,
            });
            rec.layer_wl.push(controller.wordlengths());
            rec.layer_nz
                .push(m.sparsity.iter().map(|&s| 1.0 - s).collect());
            let lb = controller.lookbacks();
            if !lb.is_empty() {
                rec.layer_lb.push(lb);
                rec.layer_res.push(controller.resolutions());
            }
            // PushDown-measured weight stats (sp / max|w| from the fused
            // pass) — the perf model prefers these over the device-reported
            // sparsity; empty for policies that never measure them.
            let wnz = controller.weight_nz();
            if !wnz.is_empty() {
                rec.layer_wnz.push(wnz);
                rec.layer_wmax.push(controller.weight_max_abs());
            }
            if telemetry {
                let timing = spans::take();
                sink.emit(&Event::Step {
                    step: global_step,
                    epoch,
                    loss: m.loss,
                    ce: m.ce,
                    acc: m.acc,
                    gnorm: m.grad_norm.iter().cloned().fold(0.0, f32::max),
                    wl: controller.wordlengths(),
                    nz: m.sparsity.iter().map(|&s| 1.0 - s).collect(),
                    lb: controller.lookbacks(),
                    res: controller.resolutions(),
                    wnz: controller.weight_nz(),
                    wmax: controller.weight_max_abs(),
                });
                emit_new_switches(sink, controller.pending_events(), &mut emitted_switches);
                sink.emit(&Event::StepTiming {
                    step: global_step,
                    quant_ms: timing[spans::Phase::Quant as usize],
                    gemm_ms: timing[spans::Phase::Gemm as usize],
                    pack_ms: timing[spans::Phase::Pack as usize],
                    epilogue_ms: timing[spans::Phase::Epilogue as usize],
                });
            }
            if cfg.log_every > 0 && global_step % cfg.log_every as u64 == 0 {
                eprintln!(
                    "[{}/{}] epoch {epoch} step {global_step}: loss {:.4} acc {:.3} wl {:?}",
                    cfg.artifact,
                    controller.name(),
                    m.loss,
                    m.acc,
                    controller.wordlengths()
                );
            }
        }
        // Epoch boundary: AdaPT's whole-net PushDown re-sync (parallel per
        // layer) / MuPPET's ladder switch. Wall time is recorded separately —
        // it is the host-side overhead the perf model bounds with eq. 6/7.
        let t_sync = Instant::now();
        controller.on_epoch_end(&mut state, epoch);
        let sync_secs = t_sync.elapsed().as_secs_f64();
        rec.switch_secs += sync_secs;
        if telemetry {
            sink.emit(&Event::EpochEnd { epoch, sync_secs });
            emit_new_switches(sink, controller.pending_events(), &mut emitted_switches);
        }
        // only policies with PushDown overhead (non-empty lookbacks) have a
        // meaningful sync cost to report
        if cfg.log_every > 0 && !controller.lookbacks().is_empty() {
            eprintln!(
                "[{}/{}] epoch {epoch}: boundary sync {:.1} ms, wl {:?}",
                cfg.artifact,
                controller.name(),
                sync_secs * 1e3,
                controller.wordlengths()
            );
        }
        // ROP scheduling on the epoch's mean training loss (sec. 4.1)
        if let Some(sch) = &mut schedule {
            let tail = &rec.steps[rec.steps.len() - steps_per_epoch..];
            let mean_loss = tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32;
            hyper.lr = sch.on_epoch(mean_loss);
        }
        let last = epoch + 1 == cfg.epochs;
        if last || (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0) {
            let acc = evaluate(model, &state, &controller.qparams(), eval.as_ref())?;
            rec.evals.push((global_step, acc));
            if telemetry {
                // eval inference spans are not training step time
                spans::take();
                sink.emit(&Event::Eval {
                    step: global_step,
                    acc,
                });
            }
            if cfg.log_every > 0 {
                eprintln!(
                    "[{}/{}] epoch {epoch}: EVAL acc {acc:.4}",
                    cfg.artifact,
                    controller.name()
                );
            }
        }
    }

    rec.switches = controller
        .take_events()
        .iter()
        .map(SwitchEventLite::from)
        .collect();
    rec.wall_secs = t0.elapsed().as_secs_f64();

    if telemetry {
        sink.emit(&Event::RunEnd {
            steps: rec.steps.len(),
            wall_secs: rec.wall_secs,
            switch_secs: rec.switch_secs,
            final_ce: rec.steps.last().map(|s| s.ce).unwrap_or(0.0),
        });
        for e in sink.sync() {
            eprintln!("[telemetry] write error: {e}");
        }
        spans::set_enabled(false);
    }

    let final_qparams = controller.qparams();
    let final_wordlengths = controller.wordlengths();
    Ok(TrainOutcome {
        record: rec,
        state,
        final_qparams,
        final_wordlengths,
    })
}
