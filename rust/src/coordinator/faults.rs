//! Deterministic fault injection for the robustness drills.
//!
//! A [`FaultPlan`] is a set of (kind, trigger-index) pairs with bounded
//! fire counts. Sites poll the plan at exact, deterministic indices (the
//! global training step, the per-process checkpoint write ordinal, the
//! serve batch sequence number), so a drill replays identically run after
//! run — the property every resume-parity test leans on.
//!
//! Plans come from code (tests) or from the `ADAPT_FAULTS` environment
//! variable, e.g.:
//!
//! ```text
//! ADAPT_FAULTS=step:17=nan_loss,ckpt:2=truncate,step:40=crash
//! ```
//!
//! Grammar: comma-separated `site:index=action[@times]` clauses where
//! `site` is `step` (actions `nan_loss`, `crash`), `ckpt` (actions
//! `truncate`, `bitflip`; index = checkpoint write ordinal) or `serve`
//! (action `panic`; index = worker batch sequence). `times` is a decimal
//! count or `inf` (default 1).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// What to break, at which site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Replace the step's loss/CE/grad norms with NaN before the
    /// divergence guard sees them (`step:N=nan_loss`).
    NanLoss,
    /// Abort the run right after step N's bookkeeping, as a process kill
    /// would (`step:N=crash`).
    Crash,
    /// Truncate the Nth checkpoint image before it hits disk
    /// (`ckpt:N=truncate`).
    CkptTruncate,
    /// Flip one bit in the Nth checkpoint image (`ckpt:N=bitflip`).
    CkptBitFlip,
    /// Panic inside the serve worker on batch N (`serve:N=panic`).
    ServePanic,
}

/// Checkpoint-image corruption mode, derived from a fired [`FaultKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptFault {
    Truncate,
    BitFlip,
}

#[derive(Debug)]
struct FaultSpec {
    kind: FaultKind,
    at: u64,
    /// remaining fire budget; `u64::MAX` means unlimited
    remaining: AtomicU64,
}

/// A deterministic set of injected faults. Cheap to share (`Arc`), safe to
/// poll from worker threads.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// The empty plan: nothing ever fires.
    pub fn none() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::default())
    }

    /// Parse the `ADAPT_FAULTS` grammar (see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (site_idx, action) = clause
                .split_once('=')
                .with_context(|| format!("fault clause `{clause}` missing `=`"))?;
            let (site, idx) = site_idx
                .split_once(':')
                .with_context(|| format!("fault site `{site_idx}` missing `:index`"))?;
            let at: u64 = idx
                .trim()
                .parse()
                .with_context(|| format!("bad fault index `{idx}`"))?;
            let (action, times) = match action.split_once('@') {
                Some((a, t)) => {
                    let times = if t.trim() == "inf" {
                        u64::MAX
                    } else {
                        t.trim()
                            .parse()
                            .with_context(|| format!("bad fault count `{t}`"))?
                    };
                    (a.trim(), times)
                }
                None => (action.trim(), 1),
            };
            let kind = match (site.trim(), action) {
                ("step", "nan_loss") => FaultKind::NanLoss,
                ("step", "crash") => FaultKind::Crash,
                ("ckpt", "truncate") => FaultKind::CkptTruncate,
                ("ckpt", "bitflip") => FaultKind::CkptBitFlip,
                ("serve", "panic") => FaultKind::ServePanic,
                (s, a) => bail!("unknown fault `{s}:{a}` in clause `{clause}`"),
            };
            plan = plan.with(kind, at, times);
        }
        Ok(plan)
    }

    /// Build a plan from `ADAPT_FAULTS` (empty plan when unset).
    pub fn from_env() -> Result<Arc<FaultPlan>> {
        match std::env::var("ADAPT_FAULTS") {
            Ok(spec) => Ok(Arc::new(FaultPlan::parse(&spec)?)),
            Err(_) => Ok(FaultPlan::none()),
        }
    }

    /// Add a fault firing up to `times` times (`u64::MAX` = unlimited)
    /// when its site reaches index `at`.
    pub fn with(mut self, kind: FaultKind, at: u64, times: u64) -> FaultPlan {
        self.faults.push(FaultSpec {
            kind,
            at,
            remaining: AtomicU64::new(times),
        });
        self
    }

    /// NaN-poison the metrics of global step `at` (once).
    pub fn nan_loss_at(self, at: u64) -> FaultPlan {
        self.with(FaultKind::NanLoss, at, 1)
    }

    /// Kill the run right after global step `at` (once).
    pub fn crash_at(self, at: u64) -> FaultPlan {
        self.with(FaultKind::Crash, at, 1)
    }

    /// Truncate the `at`-th checkpoint image written by this process.
    pub fn ckpt_truncate(self, at: u64) -> FaultPlan {
        self.with(FaultKind::CkptTruncate, at, 1)
    }

    /// Bit-flip the `at`-th checkpoint image written by this process.
    pub fn ckpt_bitflip(self, at: u64) -> FaultPlan {
        self.with(FaultKind::CkptBitFlip, at, 1)
    }

    /// Panic the serve worker handling batch sequence number `at`.
    pub fn serve_panic_at(self, at: u64) -> FaultPlan {
        self.with(FaultKind::ServePanic, at, 1)
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Poll the plan: does a fault of `kind` fire at site index `at`?
    /// Consumes one unit of the fault's budget when it does (unlimited
    /// budgets are never decremented), so `@1` faults fire exactly once
    /// even when several threads race on the same index.
    pub fn fire(&self, kind: FaultKind, at: u64) -> bool {
        for f in &self.faults {
            if f.kind != kind || f.at != at {
                continue;
            }
            let took = f
                .remaining
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |r| match r {
                    0 => None,
                    u64::MAX => Some(u64::MAX),
                    n => Some(n - 1),
                })
                .is_ok();
            if took {
                return true;
            }
        }
        false
    }

    /// Checkpoint-site convenience: which corruption (if any) fires for
    /// checkpoint write ordinal `k`?
    pub fn ckpt_fault(&self, k: u64) -> Option<CkptFault> {
        if self.fire(FaultKind::CkptTruncate, k) {
            Some(CkptFault::Truncate)
        } else if self.fire(FaultKind::CkptBitFlip, k) {
            Some(CkptFault::BitFlip)
        } else {
            None
        }
    }
}

/// Apply a checkpoint corruption to an encoded image, deterministically:
/// truncation cuts to half length, the bit flip lands at offset len/3.
pub fn corrupt_image(bytes: &mut Vec<u8>, f: CkptFault) {
    match f {
        CkptFault::Truncate => {
            let keep = bytes.len() / 2;
            bytes.truncate(keep);
        }
        CkptFault::BitFlip => {
            let i = bytes.len() / 3;
            if i < bytes.len() {
                bytes[i] ^= 0x10;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("step:17=nan_loss, ckpt:2=truncate, step:40=crash@3, serve:0=panic, ckpt:5=bitflip@inf").unwrap();
        assert!(p.fire(FaultKind::NanLoss, 17));
        assert!(!p.fire(FaultKind::NanLoss, 17), "@1 fires once");
        assert!(!p.fire(FaultKind::NanLoss, 18));
        assert_eq!(p.ckpt_fault(2), Some(CkptFault::Truncate));
        assert_eq!(p.ckpt_fault(2), None);
        for _ in 0..3 {
            assert!(p.fire(FaultKind::Crash, 40));
        }
        assert!(!p.fire(FaultKind::Crash, 40), "@3 exhausted");
        assert!(p.fire(FaultKind::ServePanic, 0));
        for _ in 0..10 {
            assert_eq!(p.ckpt_fault(5), Some(CkptFault::BitFlip), "@inf never drains");
        }
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        assert!(FaultPlan::parse("step:17").is_err(), "missing action");
        assert!(FaultPlan::parse("step=nan_loss").is_err(), "missing index");
        assert!(FaultPlan::parse("step:x=nan_loss").is_err(), "bad index");
        assert!(FaultPlan::parse("step:1=explode").is_err(), "unknown action");
        assert!(FaultPlan::parse("disk:1=truncate").is_err(), "unknown site");
        assert!(FaultPlan::parse("step:1=crash@z").is_err(), "bad count");
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::none();
        for at in 0..100 {
            assert!(!p.fire(FaultKind::NanLoss, at));
            assert!(p.ckpt_fault(at).is_none());
        }
    }

    #[test]
    fn corrupt_image_is_deterministic() {
        let img: Vec<u8> = (0..=255u8).collect();
        let mut a = img.clone();
        let mut b = img.clone();
        corrupt_image(&mut a, CkptFault::Truncate);
        corrupt_image(&mut b, CkptFault::Truncate);
        assert_eq!(a, b);
        assert_eq!(a.len(), 128);
        let mut c = img.clone();
        corrupt_image(&mut c, CkptFault::BitFlip);
        let diff: Vec<usize> = (0..img.len()).filter(|&i| img[i] != c[i]).collect();
        assert_eq!(diff, vec![img.len() / 3]);
        assert_eq!(img[diff[0]] ^ c[diff[0]], 0x10);
    }
}
