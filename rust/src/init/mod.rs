//! Weight initializers (sec. 3.1 + the fig. 2 initializer study).
//!
//! AdaPT initialises with fan-in truncated-normal variance scaling (TNVS);
//! the fig. 2 study compares it against the common zoo. All initializers
//! are implemented from scratch on the in-tree PRNG so runs are fully
//! deterministic given a seed.

use crate::runtime::manifest::{Manifest, ParamInfo};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Initializer {
    /// Fan-in truncated normal variance scaling — AdaPT's default (sec. 3.1).
    Tnvs,
    RandomNormal,
    TruncatedNormal,
    RandomUniform,
    GlorotNormal,
    GlorotUniform,
    HeNormal,
    HeUniform,
    LecunNormal,
    LecunUniform,
}

pub const ALL_INITIALIZERS: &[Initializer] = &[
    Initializer::Tnvs,
    Initializer::RandomNormal,
    Initializer::TruncatedNormal,
    Initializer::RandomUniform,
    Initializer::GlorotNormal,
    Initializer::GlorotUniform,
    Initializer::HeNormal,
    Initializer::HeUniform,
    Initializer::LecunNormal,
    Initializer::LecunUniform,
];

impl Initializer {
    pub fn name(&self) -> &'static str {
        match self {
            Initializer::Tnvs => "tnvs",
            Initializer::RandomNormal => "random_normal",
            Initializer::TruncatedNormal => "truncated_normal",
            Initializer::RandomUniform => "random_uniform",
            Initializer::GlorotNormal => "glorot_normal",
            Initializer::GlorotUniform => "glorot_uniform",
            Initializer::HeNormal => "he_normal",
            Initializer::HeUniform => "he_uniform",
            Initializer::LecunNormal => "lecun_normal",
            Initializer::LecunUniform => "lecun_uniform",
        }
    }

    pub fn from_name(s: &str) -> Option<Initializer> {
        ALL_INITIALIZERS.iter().copied().find(|i| i.name() == s)
    }

    /// Fill one kernel tensor. `fan_in`/`fan_out` from the param spec;
    /// `scale` is the TNVS empirical scaling factor s (sec. 3.1).
    pub fn sample(&self, rng: &mut Rng, n: usize, fan_in: usize, fan_out: usize, scale: f64) -> Vec<f32> {
        let fi = fan_in.max(1) as f64;
        let fo = fan_out.max(1) as f64;
        let mut out = Vec::with_capacity(n);
        match self {
            Initializer::Tnvs => {
                // W ~ N(0, s/fan_in) truncated at +-sqrt(3 s / fan_in)
                let sigma = (scale / fi).sqrt();
                let a = (3.0 * scale / fi).sqrt();
                for _ in 0..n {
                    out.push(rng.truncated_normal(0.0, sigma, a) as f32);
                }
            }
            Initializer::RandomNormal => {
                for _ in 0..n {
                    out.push((rng.normal() * 0.05) as f32);
                }
            }
            Initializer::TruncatedNormal => {
                for _ in 0..n {
                    out.push(rng.truncated_normal(0.0, 0.05, 0.1) as f32);
                }
            }
            Initializer::RandomUniform => {
                for _ in 0..n {
                    out.push(rng.uniform_in(-0.05, 0.05) as f32);
                }
            }
            Initializer::GlorotNormal => {
                let sigma = (2.0 / (fi + fo)).sqrt();
                for _ in 0..n {
                    out.push((rng.normal() * sigma) as f32);
                }
            }
            Initializer::GlorotUniform => {
                let a = (6.0 / (fi + fo)).sqrt();
                for _ in 0..n {
                    out.push(rng.uniform_in(-a, a) as f32);
                }
            }
            Initializer::HeNormal => {
                let sigma = (2.0 / fi).sqrt();
                for _ in 0..n {
                    out.push((rng.normal() * sigma) as f32);
                }
            }
            Initializer::HeUniform => {
                let a = (6.0 / fi).sqrt();
                for _ in 0..n {
                    out.push(rng.uniform_in(-a, a) as f32);
                }
            }
            Initializer::LecunNormal => {
                let sigma = (1.0 / fi).sqrt();
                for _ in 0..n {
                    out.push((rng.normal() * sigma) as f32);
                }
            }
            Initializer::LecunUniform => {
                let a = (3.0 / fi).sqrt();
                for _ in 0..n {
                    out.push(rng.uniform_in(-a, a) as f32);
                }
            }
        }
        out
    }
}

fn fan_out_of(p: &ParamInfo) -> usize {
    // conv kernels are HWIO; dense kernels are (in, out)
    match p.shape.len() {
        4 => p.shape[0] * p.shape[1] * p.shape[3],
        2 => p.shape[1],
        _ => p.elems(),
    }
}

/// Initialise the full parameter list of a model per manifest specs.
/// Kernels use `init`; biases/betas zero; gammas one.
pub fn init_params(man: &Manifest, init: Initializer, scale: f64, seed: u64) -> Vec<Vec<f32>> {
    let base = Rng::seed_from(seed);
    man.params
        .iter()
        .enumerate()
        .map(|(i, p)| match p.kind.as_str() {
            "kernel" => {
                let mut rng = base.fold(i as u64 + 1);
                init.sample(&mut rng, p.elems(), p.fan_in, fan_out_of(p), scale)
            }
            "gamma" => vec![1.0; p.elems()],
            _ => vec![0.0; p.elems()],
        })
        .collect()
}

/// Fresh gradient-diversity accumulators (zeros, one per quantizable kernel).
pub fn init_gsum(man: &Manifest) -> Vec<Vec<f32>> {
    man.params
        .iter()
        .filter(|p| p.quantizable)
        .map(|p| vec![0.0; p.elems()])
        .collect()
}

/// BN running stats: means zero, vars one.
pub fn init_bn(man: &Manifest) -> Vec<Vec<f32>> {
    man.bn_state
        .iter()
        .map(|s| {
            if s.name.ends_with(".var") {
                vec![1.0; s.elems()]
            } else {
                vec![0.0; s.elems()]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tnvs_respects_truncation() {
        let mut rng = Rng::seed_from(0);
        let v = Initializer::Tnvs.sample(&mut rng, 10000, 100, 50, 1.0);
        let bound = (3.0f64 / 100.0).sqrt() as f32;
        assert!(v.iter().all(|x| x.abs() <= bound + 1e-6));
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.01);
    }

    #[test]
    fn he_normal_variance() {
        let mut rng = Rng::seed_from(1);
        let v = Initializer::HeNormal.sample(&mut rng, 50000, 64, 64, 1.0);
        let var: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        assert!((var - 2.0 / 64.0).abs() < 0.005, "{var}");
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Rng::seed_from(2);
        let a = (6.0f64 / (32.0 + 16.0)).sqrt() as f32;
        let v = Initializer::GlorotUniform.sample(&mut rng, 5000, 32, 16, 1.0);
        assert!(v.iter().all(|x| x.abs() <= a));
    }

    #[test]
    fn name_round_trip() {
        for &i in ALL_INITIALIZERS {
            assert_eq!(Initializer::from_name(i.name()), Some(i));
        }
        assert_eq!(Initializer::from_name("bogus"), None);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(3);
        let mut b = Rng::seed_from(3);
        let va = Initializer::Tnvs.sample(&mut a, 100, 10, 10, 1.0);
        let vb = Initializer::Tnvs.sample(&mut b, 100, 10, 10, 1.0);
        assert_eq!(va, vb);
    }
}
