//! Signed fixed-point format `<WL, FL>` (sec. 2.1 of the paper).
//!
//! A value v is stored as an integer q with v = q * 2^-FL and
//! q in [-2^(WL-1), 2^(WL-1)-1]. WL counts ALL bits (sign + integer +
//! fraction); FL counts fraction bits. The Rust side mirrors the L1 Pallas
//! kernel semantics exactly so PushDown candidate evaluation (host-side)
//! agrees with what the device will compute.

use std::fmt;

pub const WL_MAX: u8 = 32;
pub const FL_MAX: u8 = 31;

/// A `<WL, FL>` pair: total word length (sign + integer + fraction bits)
/// and fraction length.
///
/// ```
/// use adapt::fixedpoint::FixedPointFormat;
///
/// let fmt = FixedPointFormat::new(8, 4); // the paper's initial format
/// assert_eq!(fmt.quantize_nr(0.3), 0.3125); // snaps to the 1/16 grid
/// assert_eq!(fmt.max_value(), 127.0 / 16.0); // q in [-128, 127]
/// assert!(fmt.representable(-0.5));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FixedPointFormat {
    pub wl: u8,
    pub fl: u8,
}

impl FixedPointFormat {
    pub fn new(wl: u8, fl: u8) -> Self {
        let wl = wl.clamp(2, WL_MAX);
        let fl = fl.min(FL_MAX).min(wl - 1);
        FixedPointFormat { wl, fl }
    }

    /// The paper's initial quantization <8, 4> (sec. 4.1.1).
    pub fn initial() -> Self {
        FixedPointFormat { wl: 8, fl: 4 }
    }

    /// Widest (effectively lossless at f32 master precision).
    pub fn full() -> Self {
        FixedPointFormat { wl: 32, fl: 16 }
    }

    #[inline]
    pub fn scale(&self) -> f32 {
        (2.0f32).powi(self.fl as i32)
    }

    #[inline]
    pub fn qmin(&self) -> f32 {
        -((1u64 << (self.wl - 1)) as f32)
    }

    #[inline]
    pub fn qmax(&self) -> f32 {
        ((1u64 << (self.wl - 1)) - 1) as f32
    }

    /// Smallest representable positive value (one ULP).
    #[inline]
    pub fn ulp(&self) -> f32 {
        1.0 / self.scale()
    }

    /// Largest representable value.
    #[inline]
    pub fn max_value(&self) -> f32 {
        self.qmax() / self.scale()
    }

    /// Most negative representable value.
    #[inline]
    pub fn min_value(&self) -> f32 {
        self.qmin() / self.scale()
    }

    /// Integer bits (excluding sign): WL = 1 + IL + FL.
    pub fn integer_bits(&self) -> u8 {
        self.wl - 1 - self.fl.min(self.wl - 1)
    }

    /// Smallest format whose range covers `max_abs` at fraction length `fl`
    /// without clamping. If sign + integer + fraction would exceed 32 bits,
    /// the fraction length is reduced (range wins over precision — clamping
    /// large weights is catastrophic, losing low bits is graceful).
    pub fn covering(max_abs: f32, fl: u8) -> Self {
        let mut il = 0u8;
        while il < WL_MAX
            && ((1u64 << il) as f32) <= max_abs + 0.5 / (2.0f32).powi(fl as i32)
        {
            il += 1;
        }
        let fl = fl.min(WL_MAX - 1 - il.min(WL_MAX - 1));
        FixedPointFormat::new(1 + il + fl, fl)
    }

    /// Nearest-rounding quantize of one value (round-half-to-even, matching
    /// jnp.round in the L1 kernel).
    #[inline]
    pub fn quantize_nr(&self, x: f32) -> f32 {
        let q = round_half_even(x * self.scale());
        q.clamp(self.qmin(), self.qmax()) / self.scale()
    }

    /// Stochastic-rounding quantize with external noise u in [0,1):
    /// floor(x*s + u) — the exact L1 kernel computation.
    #[inline]
    pub fn quantize_sr(&self, x: f32, u: f32) -> f32 {
        let q = (x * self.scale() + u).floor();
        q.clamp(self.qmin(), self.qmax()) / self.scale()
    }

    /// Is x exactly representable?
    pub fn representable(&self, x: f32) -> bool {
        let q = x * self.scale();
        q == q.round() && q >= self.qmin() && q <= self.qmax()
    }

    /// qparams row for the artifact input: [scale, qmin, qmax, enable, wl].
    pub fn qparams_row(&self, enable: f32) -> [f32; 5] {
        [self.scale(), self.qmin(), self.qmax(), enable, self.wl as f32]
    }

    /// Inverse of [`qparams_row`](Self::qparams_row): recover `(format,
    /// enable)` from a runtime qparams row. Returns `None` when the row does
    /// not describe a signed power-of-two `<WL, FL>` grid (e.g. a corrupted
    /// tensor); rows produced by `qparams_row` always round-trip. Used by
    /// the native backend tests to cross-check the interpreter's generic
    /// row arithmetic against the typed format kernels.
    pub fn from_qparams_row(row: &[f32; 5]) -> Option<(FixedPointFormat, bool)> {
        let wl = row[4];
        if !(2.0..=WL_MAX as f32).contains(&wl) || wl.fract() != 0.0 {
            return None;
        }
        // scale must be an exact positive power of two 2^FL with FL >= 0:
        // inspect the bits rather than trusting log2 rounding.
        let bits = row[0].to_bits();
        if bits >> 31 != 0 || bits & 0x007F_FFFF != 0 {
            return None;
        }
        let fl = ((bits >> 23) & 0xFF) as i32 - 127;
        if !(0..=FL_MAX as i32).contains(&fl) {
            return None;
        }
        let fmt = FixedPointFormat::new(wl as u8, fl as u8);
        if fmt.scale() != row[0] || fmt.qmin() != row[1] || fmt.qmax() != row[2] {
            return None;
        }
        Some((fmt, row[3] > 0.5))
    }
}

/// Magic constant of the round-to-nearest-even trick: 1.5·2^23. Adding it
/// forces an |x| < 2^22 intermediate into [2^23, 2^24), where the f32 ULP is
/// exactly 1, so IEEE default rounding of the addition IS round-half-even.
/// Shared with the chunked `quantize_bin` kernel so both compute
/// bit-identical lanes.
pub const RNE_MAGIC: f32 = 12_582_912.0;

/// |x| bound (2^22) below which the magic-number RNE is exact. Above it the
/// slow scalar [`round_half_even`] must be used: |x| ≥ 2^23 is already
/// integral, and the [2^22, 2^23) band has representable halves but no valid
/// magic constant.
pub const RNE_FAST_LIMIT: f32 = 4_194_304.0;

/// Branch-light round-half-to-even used by the fused quantize+bin kernel.
///
/// For |x| < [`RNE_FAST_LIMIT`] the classic magic-number trick applies (see
/// [`RNE_MAGIC`]); the subtraction is then exact, and the tie parity is
/// preserved because the magic constant is even. Outside that range the
/// scalar reference takes over.
///
/// Agrees with [`round_half_even`] on every input (NaN/±inf included), up to
/// the sign of a zero result: negatives in (-0.5, -0.0] round to -0.0 via the
/// scalar path but to +0.0 here. ±0.0 compare equal and scale/bin/clamp
/// identically, so the fused engine stays count-exact with the naive path —
/// asserted by the sweep below and the cross-format property tests in
/// `rust/tests/quant_fused_parallel.rs`.
#[inline]
pub fn round_half_even_fast(x: f32) -> f32 {
    if x.abs() < RNE_FAST_LIMIT {
        (x + RNE_MAGIC) - RNE_MAGIC
    } else {
        round_half_even(x)
    }
}

/// f32 round-half-to-even (Rust's `round()` rounds half away from zero;
/// XLA/jnp round half to even, and the L1/L3 implementations must agree).
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // exactly .5 -> choose the even neighbour
        let even = 2.0 * (x / 2.0).round();
        if (even - x).abs() <= 0.5 {
            even
        } else {
            r
        }
    } else {
        r
    }
}

impl fmt::Display for FixedPointFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<{},{}>", self.wl, self.fl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_is_8_4() {
        let f = FixedPointFormat::initial();
        assert_eq!((f.wl, f.fl), (8, 4));
        assert_eq!(f.scale(), 16.0);
        assert_eq!(f.qmin(), -128.0);
        assert_eq!(f.qmax(), 127.0);
        assert_eq!(f.max_value(), 127.0 / 16.0);
    }

    #[test]
    fn quantize_nr_on_grid() {
        let f = FixedPointFormat::new(8, 4);
        for &x in &[0.0f32, 0.06, -0.06, 1.23, -7.9, 100.0, -100.0] {
            let q = f.quantize_nr(x);
            assert!(f.representable(q), "{x} -> {q}");
            if x.abs() <= f.max_value() {
                assert!((q - x).abs() <= f.ulp() / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn quantize_sr_bounds() {
        let f = FixedPointFormat::new(6, 3);
        for i in 0..200 {
            let x = -3.0 + 0.03 * i as f32;
            for &u in &[0.0f32, 0.25, 0.5, 0.9999] {
                let q = f.quantize_sr(x, u);
                assert!(f.representable(q));
                if x >= f.min_value() && x <= f.max_value() {
                    assert!((q - x).abs() <= f.ulp() + 1e-6);
                }
            }
        }
    }

    #[test]
    fn half_even_matches_ieee() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(0.4), 0.0);
        assert_eq!(round_half_even(0.6), 1.0);
    }

    #[test]
    fn fast_matches_reference() {
        // dense sweep around every regime the magic-number trick must hit:
        // subnormals, halves, the 2^22 branch point, the 2^23 integrality
        // threshold, and non-finite inputs
        let mut probes: Vec<f32> = vec![
            0.0, -0.0, 0.25, -0.25, 0.5, -0.5, 0.75, -0.75, 1.5, -1.5, 2.5, -2.5,
            4_194_303.5, -4_194_303.5, 4_194_304.5, -4_194_304.5, 6_291_456.5,
            8_388_607.5, 8_388_608.0, -8_388_608.0, 1e30, -1e30,
            f32::INFINITY, f32::NEG_INFINITY, f32::MIN_POSITIVE,
        ];
        let mut r = crate::util::rng::Rng::seed_from(17);
        for _ in 0..20_000 {
            probes.push((r.uniform_in(-10.0, 10.0)) as f32);
            probes.push((r.uniform_in(-5e6, 5e6)) as f32);
            let half = (r.uniform_in(-1e6, 1e6) as f32).trunc() + 0.5;
            probes.push(half);
        }
        for x in probes {
            let slow = round_half_even(x);
            let fast = round_half_even_fast(x);
            assert!(
                slow == fast || (slow.is_nan() && fast.is_nan()),
                "{x}: ref {slow} vs fast {fast}"
            );
        }
        assert!(round_half_even_fast(f32::NAN).is_nan());
    }

    #[test]
    fn covering_picks_enough_integer_bits() {
        let f = FixedPointFormat::covering(5.3, 4);
        assert!(f.max_value() >= 5.3);
        let g = FixedPointFormat::covering(0.4, 4);
        assert!(g.wl <= 6);
        assert!(g.max_value() >= 0.4);
    }

    #[test]
    fn qparams_row_round_trips() {
        for (wl, fl) in [(2u8, 1u8), (8, 4), (12, 8), (16, 10), (24, 12), (32, 16)] {
            let fmt = FixedPointFormat::new(wl, fl);
            for enable in [0.0f32, 1.0] {
                let row = fmt.qparams_row(enable);
                assert_eq!(
                    FixedPointFormat::from_qparams_row(&row),
                    Some((fmt, enable > 0.5)),
                    "<{wl},{fl}> enable={enable}"
                );
            }
        }
        // rows that do not describe a plain signed <WL,FL> grid are rejected
        assert_eq!(
            FixedPointFormat::from_qparams_row(&[3.0, -128.0, 127.0, 1.0, 8.0]),
            None,
            "non-power-of-two scale"
        );
        assert_eq!(
            FixedPointFormat::from_qparams_row(&[16.0, -100.0, 127.0, 1.0, 8.0]),
            None,
            "clamp bounds off the signed grid"
        );
        assert_eq!(
            FixedPointFormat::from_qparams_row(&[0.125, -128.0, 127.0, 1.0, 8.0]),
            None,
            "negative-power scale (block floating point, not <WL,FL>)"
        );
        assert_eq!(
            FixedPointFormat::from_qparams_row(&[0.0, -128.0, 127.0, 1.0, 8.0]),
            None
        );
    }

    #[test]
    fn clamp_constructor() {
        let f = FixedPointFormat::new(40, 60);
        assert_eq!(f.wl, 32);
        assert!(f.fl < f.wl);
    }
}
