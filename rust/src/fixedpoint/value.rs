//! Precision-generic storage values for the real integer compute path.
//!
//! A fake-quantized tensor under a `<WL, FL>` row holds values `m · 2^-FL`
//! with integral `m ∈ [qmin, qmax]` — every value IS an integer code times a
//! power-of-two scale. [`QuantValue`] abstracts over how that code is
//! *stored* and *accumulated*: `f32` keeps today's float passthrough
//! (codes-at-scale, float accumulation — bit-identical to the existing
//! kernels), while `i8`/`i16` store the raw code in 8/16 bits and
//! accumulate in a widened integer type where every multiply-add is exact.
//!
//! The split matters for the GEMM panels in `runtime::native::gemm`: an
//! `i8` panel packs 4× more codes per cache line than the f32 panel before
//! any SIMD, and the widened dot product is the TRUE fixed-point sum — the
//! paper's "execute at the selected word length" claim (eq. 8/9) made
//! runnable instead of merely modelled by `perfmodel`.
//!
//! # Accumulator widths
//!
//! * `i8 × i8 → i32`: each product is bounded by `2^7 · 2^7 = 2^14`, so a
//!   depth-`k` sum stays inside `i32` for every `k ≤ 2^16` (the native
//!   snapshot dispatch enforces that depth bound before choosing `i8`).
//! * `i16 × i16 → i64`: a single product can reach `2^30`; two already
//!   overflow `i32`, so the `i16` path MUST widen to `i64` (where sums are
//!   safe beyond any realistic fan-in).
//! * `f32` "widens" to `f32` — the identity passthrough used to prove the
//!   generic kernels reproduce the float fold bit for bit.
//!
//! ```
//! use adapt::fixedpoint::{FixedPointFormat, QuantValue};
//!
//! let fmt = FixedPointFormat::new(8, 4);
//! // 0.3125 on the <8,4> grid is the integer code 5
//! let code = <i8 as QuantValue>::from_code(0.3125 * fmt.scale());
//! assert_eq!(code, 5);
//! assert!(<i8 as QuantValue>::fits(fmt));
//! // widening multiply-accumulate is exact: 5·5 + 0 = 25
//! assert_eq!(<i8 as QuantValue>::mul_acc(code, code, 0), 25);
//! ```

use super::format::FixedPointFormat;

/// A storage type for fixed-point integer codes plus its widened
/// accumulator (module docs). Implemented for `f32` (zero-cost float
/// passthrough), `i8` and `i16` (saturating narrow storage, exact widened
/// accumulation).
pub trait QuantValue: Copy + Send + Sync + 'static {
    /// Widened accumulator: exact for every depth the dispatch admits.
    type Acc: Copy + Send + Sync + 'static;
    /// Storage width in bits.
    const BITS: u8;
    /// The zero code (panel padding).
    const ZERO: Self;
    /// The empty accumulator.
    const ZERO_ACC: Self::Acc;

    /// Store an integer code given as f32 (`value · 2^FL`, already
    /// integral for on-grid inputs). Out-of-range codes saturate; NaN
    /// stores zero — the semantics of Rust's float→int `as` cast.
    fn from_code(code: f32) -> Self;

    /// The stored code back as f32 (exact: every code fits f32's mantissa).
    fn to_f32(self) -> f32;

    /// `acc + a·b`, widening before the multiply so the result is exact
    /// for the integer impls (and the plain float fold for `f32`).
    fn mul_acc(a: Self, b: Self, acc: Self::Acc) -> Self::Acc;

    /// Fold an accumulator back to f32 for the requant epilogue.
    fn acc_to_f32(acc: Self::Acc) -> f32;

    /// Can every code of `fmt` be stored losslessly in this type?
    fn fits(fmt: FixedPointFormat) -> bool;
}

/// Zero-cost float passthrough: codes are stored at their original scale
/// and accumulated with the exact `acc + a * b` fold of the f32 GEMM
/// micro-kernel, so generic kernels instantiated at `f32` are bit-identical
/// to the hand-written float path.
impl QuantValue for f32 {
    type Acc = f32;
    const BITS: u8 = 32;
    const ZERO: f32 = 0.0;
    const ZERO_ACC: f32 = 0.0;

    #[inline]
    fn from_code(code: f32) -> f32 {
        code
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self
    }

    #[inline]
    fn mul_acc(a: f32, b: f32, acc: f32) -> f32 {
        acc + a * b
    }

    #[inline]
    fn acc_to_f32(acc: f32) -> f32 {
        acc
    }

    #[inline]
    fn fits(_fmt: FixedPointFormat) -> bool {
        true
    }
}

impl QuantValue for i8 {
    type Acc = i32;
    const BITS: u8 = 8;
    const ZERO: i8 = 0;
    const ZERO_ACC: i32 = 0;

    #[inline]
    fn from_code(code: f32) -> i8 {
        code as i8
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }

    #[inline]
    fn mul_acc(a: i8, b: i8, acc: i32) -> i32 {
        acc + a as i32 * b as i32
    }

    #[inline]
    fn acc_to_f32(acc: i32) -> f32 {
        acc as f32
    }

    #[inline]
    fn fits(fmt: FixedPointFormat) -> bool {
        fmt.wl <= 8
    }
}

impl QuantValue for i16 {
    type Acc = i64;
    const BITS: u8 = 16;
    const ZERO: i16 = 0;
    const ZERO_ACC: i64 = 0;

    #[inline]
    fn from_code(code: f32) -> i16 {
        code as i16
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }

    #[inline]
    fn mul_acc(a: i16, b: i16, acc: i64) -> i64 {
        // the product itself is exact in i32 (|p| <= 2^30) but the SUM is
        // not — widen before accumulating (module docs)
        acc + a as i64 * b as i64
    }

    #[inline]
    fn acc_to_f32(acc: i64) -> f32 {
        acc as f32
    }

    #[inline]
    fn fits(fmt: FixedPointFormat) -> bool {
        fmt.wl <= 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrow_storage_saturates_and_round_trips() {
        assert_eq!(<i8 as QuantValue>::from_code(5.0), 5);
        assert_eq!(<i8 as QuantValue>::from_code(-128.0), -128);
        assert_eq!(<i8 as QuantValue>::from_code(127.0), 127);
        assert_eq!(<i8 as QuantValue>::from_code(200.0), 127, "saturate high");
        assert_eq!(<i8 as QuantValue>::from_code(-200.0), -128, "saturate low");
        assert_eq!(<i8 as QuantValue>::from_code(f32::NAN), 0, "NaN stores zero");
        assert_eq!(<i16 as QuantValue>::from_code(-32768.0), -32768);
        assert_eq!(<i16 as QuantValue>::from_code(1e9), 32767, "saturate high");
        for c in [-128i8, -1, 0, 1, 127] {
            assert_eq!(c.to_f32(), c as f32);
        }
    }

    #[test]
    fn accumulation_is_exact_at_the_extremes() {
        // i8: the worst single product and a long sum of it
        let p = <i8 as QuantValue>::mul_acc(-128, -128, 0);
        assert_eq!(p, 16384);
        let mut acc = 0i32;
        for _ in 0..1 << 16 {
            acc = <i8 as QuantValue>::mul_acc(-128, 127, acc);
        }
        assert_eq!(acc, -(128 * 127) * (1 << 16));
        // i16: one extreme product already needs more than half of i32
        let p = <i16 as QuantValue>::mul_acc(-32768, -32768, 0);
        assert_eq!(p, 1 << 30);
        let two = <i16 as QuantValue>::mul_acc(-32768, -32768, p);
        assert_eq!(two, 1i64 << 31, "two extreme products exceed i32");
    }

    #[test]
    fn f32_passthrough_matches_the_float_fold() {
        let (a, b, acc) = (1.1f32, -2.3f32, 0.7f32);
        let got = <f32 as QuantValue>::mul_acc(a, b, acc);
        assert_eq!(got.to_bits(), (acc + a * b).to_bits());
        assert_eq!(<f32 as QuantValue>::from_code(1.25), 1.25);
    }

    #[test]
    fn fits_follows_word_length() {
        assert!(<i8 as QuantValue>::fits(FixedPointFormat::new(8, 4)));
        assert!(!<i8 as QuantValue>::fits(FixedPointFormat::new(9, 4)));
        assert!(<i16 as QuantValue>::fits(FixedPointFormat::new(16, 10)));
        assert!(!<i16 as QuantValue>::fits(FixedPointFormat::new(17, 10)));
        assert!(<f32 as QuantValue>::fits(FixedPointFormat::new(32, 16)));
    }
}
