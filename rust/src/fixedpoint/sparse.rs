//! Sparse fixed-point tensor format (CSR) for the deployed-inference path.
//!
//! The paper's inference advantage (tab. 6) comes from the trained model
//! being *both* quantized and sparsified: weights are stored at WL bits in a
//! sparse format. This module implements that storage plus a sparse
//! matrix-vector product so `examples/inference.rs` can demonstrate the
//! deployed representation end-to-end, and it supplies the exact
//! bits-per-model numbers behind the SZ column.

use super::format::FixedPointFormat;
use super::quantize::quantize_sr_into;
use crate::util::rng::Rng;

/// CSR matrix of fixed-point values; the integer codes are bit-packed at
/// WL bits each (the ASIC deployment format the paper targets).
#[derive(Debug, Clone)]
pub struct SparseFixedTensor {
    pub rows: usize,
    pub cols: usize,
    pub fmt: FixedPointFormat,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    /// Bit-packed signed integer codes, WL bits each, little-endian bit order.
    pub packed: Vec<u64>,
    pub nnz: usize,
}

impl SparseFixedTensor {
    /// Quantize a dense row-major matrix (nearest rounding) and keep only
    /// non-zeros.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize, fmt: FixedPointFormat) -> Self {
        assert_eq!(dense.len(), rows * cols);
        Self::build(rows, cols, fmt, |i| fmt.quantize_nr(dense[i]))
    }

    /// Stochastic-rounding export: quantizes the whole tensor with the
    /// allocation-free [`quantize_sr_into`] convention (`buf` is reusable
    /// across layer exports) and sparsifies the result. SR export preserves
    /// the tensor mean in expectation, which NR export does not for weights
    /// sitting between grid points.
    pub fn from_dense_sr(
        dense: &[f32],
        rows: usize,
        cols: usize,
        fmt: FixedPointFormat,
        rng: &mut Rng,
        buf: &mut Vec<f32>,
    ) -> Self {
        assert_eq!(dense.len(), rows * cols);
        quantize_sr_into(dense, fmt, rng, buf);
        let q = &*buf;
        Self::build(rows, cols, fmt, |i| q[i])
    }

    /// CSR from a dense matrix whose values are ALREADY on the `fmt` grid
    /// (e.g. the native backend's fake-quantized kernels): no re-rounding
    /// happens, so every stored non-zero code decodes bit-exactly to its
    /// input value (zeros — including a quantized `-0.0` — are simply not
    /// stored). This is the contract the sparse inference path relies on
    /// for its parity with the dense kernels.
    pub fn from_quantized(dense_q: &[f32], rows: usize, cols: usize, fmt: FixedPointFormat) -> Self {
        assert_eq!(dense_q.len(), rows * cols);
        debug_assert!(
            dense_q.iter().all(|&q| fmt.representable(q)),
            "from_quantized requires on-grid values"
        );
        Self::build(rows, cols, fmt, |i| dense_q[i])
    }

    /// CSR construction from an already-on-grid value source.
    fn build<F: FnMut(usize) -> f32>(
        rows: usize,
        cols: usize,
        fmt: FixedPointFormat,
        mut qval: F,
    ) -> Self {
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut codes: Vec<i64> = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let q = qval(r * cols + c);
                if q != 0.0 {
                    col_idx.push(c as u32);
                    codes.push((q * fmt.scale()) as i64);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let nnz = codes.len();
        let packed = pack_codes(&codes, fmt.wl);
        SparseFixedTensor {
            rows,
            cols,
            fmt,
            row_ptr,
            col_idx,
            packed,
            nnz,
        }
    }

    /// Decode the i-th stored code back to its f32 value.
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        unpack_code(&self.packed, i, self.fmt.wl) as f32 / self.fmt.scale()
    }

    /// Decode ALL stored codes into a reusable f32 buffer (cleared, then
    /// filled in storage order — `out[i] == self.value(i)`). Compute kernels
    /// decode once up front instead of bit-unpacking per multiply; the
    /// WL-bit packed words remain the deployment/storage representation.
    pub fn decode_values_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.nnz);
        for i in 0..self.nnz {
            out.push(self.value(i));
        }
    }

    /// Consume the tensor into the compute-ready CSR triple
    /// `(row_ptr, col_idx, values)` with the stored codes decoded to f32 in
    /// storage order — the layout the native sparse inference kernel
    /// ([`sparse_forward_quant_into`]) and the serving snapshot consume.
    /// The WL-bit packed words are dropped: callers that keep the tensor as
    /// the storage/deployment representation should use
    /// [`decode_values_into`](Self::decode_values_into) instead.
    ///
    /// [`sparse_forward_quant_into`]: crate::runtime::native::gemm::sparse_forward_quant_into
    pub fn into_csr_f32(self) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        let mut vals = Vec::new();
        self.decode_values_into(&mut vals);
        let SparseFixedTensor { row_ptr, col_idx, .. } = self;
        (row_ptr, col_idx, vals)
    }

    /// y = A x (dense vector input / output).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            let mut acc = 0.0f32;
            for i in s..e {
                acc += self.value(i) * x[self.col_idx[i] as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// Reconstruct the dense (quantized) matrix.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                d[r * self.cols + self.col_idx[i] as usize] = self.value(i);
            }
        }
        d
    }

    pub fn density(&self) -> f32 {
        self.nnz as f32 / (self.rows * self.cols) as f32
    }

    /// Storage cost in bits: packed values + column indices + row pointers.
    pub fn storage_bits(&self) -> u64 {
        (self.nnz as u64) * (self.fmt.wl as u64)
            + (self.col_idx.len() as u64) * 32
            + (self.row_ptr.len() as u64) * 32
    }

    /// Value-only bits (the paper's sz ignores index overhead: sz = sp * WL).
    pub fn value_bits(&self) -> u64 {
        (self.nnz as u64) * (self.fmt.wl as u64)
    }
}

fn pack_codes(codes: &[i64], wl: u8) -> Vec<u64> {
    let wl = wl as usize;
    let total_bits = codes.len() * wl;
    let mut out = vec![0u64; total_bits.div_ceil(64)];
    let mask = if wl == 64 { u64::MAX } else { (1u64 << wl) - 1 };
    for (i, &c) in codes.iter().enumerate() {
        let bits = (c as u64) & mask;
        let bit = i * wl;
        let (w, off) = (bit / 64, bit % 64);
        out[w] |= bits << off;
        if off + wl > 64 {
            out[w + 1] |= bits >> (64 - off);
        }
    }
    out
}

fn unpack_code(packed: &[u64], i: usize, wl: u8) -> i64 {
    let wl = wl as usize;
    let bit = i * wl;
    let (w, off) = (bit / 64, bit % 64);
    let mask = if wl == 64 { u64::MAX } else { (1u64 << wl) - 1 };
    let mut bits = packed[w] >> off;
    if off + wl > 64 {
        bits |= packed[w + 1] << (64 - off);
    }
    bits &= mask;
    // sign-extend from WL bits
    let sign = 1u64 << (wl - 1);
    if bits & sign != 0 {
        (bits | !mask) as i64
    } else {
        bits as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(rows: usize, cols: usize, density: f64, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from(seed);
        (0..rows * cols)
            .map(|_| {
                if r.uniform() < density {
                    r.normal() as f32
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn round_trip_dense() {
        let fmt = FixedPointFormat::new(8, 4);
        let d = random_sparse(17, 23, 0.3, 1);
        let s = SparseFixedTensor::from_dense(&d, 17, 23, fmt);
        let back = s.to_dense();
        for (a, b) in d.iter().zip(&back) {
            assert_eq!(fmt.quantize_nr(*a), *b);
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let fmt = FixedPointFormat::new(12, 8);
        let d = random_sparse(31, 19, 0.4, 2);
        let s = SparseFixedTensor::from_dense(&d, 31, 19, fmt);
        let mut r = Rng::seed_from(3);
        let x: Vec<f32> = (0..19).map(|_| r.normal() as f32).collect();
        let y = s.matvec(&x);
        let qd = s.to_dense();
        for row in 0..31 {
            let want: f32 = (0..19).map(|c| qd[row * 19 + c] * x[c]).sum();
            assert!((y[row] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn sr_export_stays_on_grid_and_close() {
        let fmt = FixedPointFormat::new(8, 4);
        let d = random_sparse(23, 17, 0.5, 7);
        let mut rng = Rng::seed_from(9);
        let mut buf = Vec::new();
        let s = SparseFixedTensor::from_dense_sr(&d, 23, 17, fmt, &mut rng, &mut buf);
        let back = s.to_dense();
        for (x, q) in d.iter().zip(&back) {
            assert!(fmt.representable(*q), "{x} -> {q} off-grid");
            if x.abs() <= fmt.max_value() {
                assert!((x - q).abs() <= fmt.ulp() + 1e-6, "{x} -> {q}");
            }
        }
        // buffer is reused allocation-free on a second export
        let cap = buf.capacity();
        let _ = SparseFixedTensor::from_dense_sr(&d, 23, 17, fmt, &mut rng, &mut buf);
        assert_eq!(buf.capacity(), cap);
        // deterministic given the rng stream
        let mut r2 = Rng::seed_from(9);
        let mut b2 = Vec::new();
        let s2 = SparseFixedTensor::from_dense_sr(&d, 23, 17, fmt, &mut r2, &mut b2);
        assert_eq!(s.to_dense(), s2.to_dense());
    }

    #[test]
    fn bit_packing_all_wordlengths() {
        for wl in 2..=32u8 {
            let fmt = FixedPointFormat::new(wl, wl / 2);
            let lo = -(1i64 << (wl - 1));
            let hi = (1i64 << (wl - 1)) - 1;
            let codes = vec![lo, hi, 0, 1, -1, lo + 1, hi - 1];
            let packed = pack_codes(&codes, wl);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(unpack_code(&packed, i, wl), c, "wl={wl} i={i}");
            }
            let _ = fmt;
        }
    }

    #[test]
    fn from_quantized_decodes_bit_exactly() {
        use crate::fixedpoint::quantize_nr_slice;
        for (wl, fl) in [(4u8, 2u8), (8, 4), (16, 10), (24, 12), (32, 16)] {
            let fmt = FixedPointFormat::new(wl, fl);
            let d = random_sparse(19, 13, 0.4, 11);
            let q = quantize_nr_slice(&d, fmt);
            let s = SparseFixedTensor::from_quantized(&q, 19, 13, fmt);
            // every stored (non-zero) value decodes to the exact input bits;
            // zeros are dropped from CSR, so a quantized -0.0 round-trips as
            // +0.0 — indistinguishable to the compute kernels
            let back = s.to_dense();
            for (a, b) in q.iter().zip(&back) {
                assert!(
                    a.to_bits() == b.to_bits() || (*a == 0.0 && *b == 0.0),
                    "<{wl},{fl}>: {a} vs {b}"
                );
            }
            // decode_values_into matches value(i) in storage order
            let mut vals = Vec::new();
            s.decode_values_into(&mut vals);
            assert_eq!(vals.len(), s.nnz);
            for (i, v) in vals.iter().enumerate() {
                assert_eq!(v.to_bits(), s.value(i).to_bits());
            }
        }
    }

    #[test]
    fn into_csr_f32_matches_storage_order() {
        let fmt = FixedPointFormat::new(8, 4);
        let d = random_sparse(9, 14, 0.3, 21);
        let s = SparseFixedTensor::from_dense(&d, 9, 14, fmt);
        let mut want = Vec::new();
        s.decode_values_into(&mut want);
        let (rp, ci) = (s.row_ptr.clone(), s.col_idx.clone());
        let (row_ptr, col_idx, vals) = s.into_csr_f32();
        assert_eq!(row_ptr, rp);
        assert_eq!(col_idx, ci);
        assert_eq!(vals.len(), want.len());
        for (a, b) in vals.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn storage_accounting() {
        let fmt = FixedPointFormat::new(8, 4);
        let d = random_sparse(10, 10, 0.5, 4);
        let s = SparseFixedTensor::from_dense(&d, 10, 10, fmt);
        assert_eq!(s.value_bits(), s.nnz as u64 * 8);
        assert!(s.storage_bits() > s.value_bits());
        assert!((s.density() - 0.5).abs() < 0.25);
    }

    #[test]
    fn empty_matrix() {
        let fmt = FixedPointFormat::new(8, 4);
        let s = SparseFixedTensor::from_dense(&[0.0; 12], 3, 4, fmt);
        assert_eq!(s.nnz, 0);
        assert_eq!(s.matvec(&[1.0; 4]), vec![0.0; 3]);
    }
}
