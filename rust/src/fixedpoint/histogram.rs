//! Empirical distribution + discrete Kullback–Leibler divergence.
//!
//! The PushDown operation (sec. 3.3) interprets a precision switch as a
//! change of encoding and measures the information lost via KL(P || Q)
//! where Q is the distribution of the float32 master weights and P the
//! distribution of their quantized counterparts, both discretised by
//! equal-width binning at resolution r^l (eq. 1, 2).

/// Equal-width histogram over [lo, hi] with `bins` buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(bins > 0);
        let (lo, hi) = if hi > lo { (lo, hi) } else { (lo, lo + 1e-12) };
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    #[inline]
    pub fn bin_of(&self, x: f32) -> usize {
        let b = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * b as f32) as isize;
        t.clamp(0, b as isize - 1) as usize
    }

    #[inline]
    pub fn add(&mut self, x: f32) {
        let i = self.bin_of(x);
        self.counts[i] += 1;
        self.total += 1;
    }

    pub fn from_slice(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    /// Probability of bin i with epsilon flooring (so KL stays finite when a
    /// bin is empty on one side only — the "information was created" case is
    /// penalised heavily but finitely).
    #[inline]
    pub fn prob(&self, i: usize, eps: f64) -> f64 {
        (self.counts[i] as f64 + eps) / (self.total as f64 + eps * self.counts.len() as f64)
    }
}

/// Discrete KL(P || Q) over two histograms with identical binning (eq. 2).
/// Returns bits (log base 2) — "the average number of bits lost through
/// changing the encoding".
pub fn kl_divergence(p: &Histogram, q: &Histogram, eps: f64) -> f64 {
    assert_eq!(p.counts.len(), q.counts.len());
    let mut kl = 0.0;
    for i in 0..p.counts.len() {
        let pi = p.prob(i, eps);
        let qi = q.prob(i, eps);
        if pi > 0.0 {
            kl += pi * (pi / qi).log2();
        }
    }
    kl.max(0.0)
}

/// KL between the EDF of `original` and of `quantized` at resolution `bins`,
/// binned over the ORIGINAL tensor's range (the encoding being abandoned).
pub fn quantization_kl(original: &[f32], quantized: &[f32], bins: usize) -> f64 {
    if original.is_empty() {
        return 0.0;
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in original {
        if !x.is_finite() {
            return f64::INFINITY;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let q = Histogram::from_slice(original, lo, hi, bins);
    let p = Histogram::from_slice(quantized, lo, hi, bins);
    kl_divergence(&p, &q, 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_distributions_zero_kl() {
        let mut r = Rng::seed_from(0);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        let kl = quantization_kl(&xs, &xs, 100);
        assert!(kl.abs() < 1e-9, "{kl}");
    }

    #[test]
    fn kl_nonnegative_and_sensitive() {
        let mut r = Rng::seed_from(1);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        // coarse quantization -> mass moves between bins -> positive KL
        let coarse: Vec<f32> = xs.iter().map(|x| (x * 2.0).round() / 2.0).collect();
        let fine: Vec<f32> = xs.iter().map(|x| (x * 4096.0).round() / 4096.0).collect();
        let kl_c = quantization_kl(&xs, &coarse, 100);
        let kl_f = quantization_kl(&xs, &fine, 100);
        assert!(kl_c > 0.0);
        assert!(kl_f < kl_c, "fine {kl_f} should lose less than coarse {kl_c}");
    }

    #[test]
    fn resolution_controls_sensitivity() {
        let mut r = Rng::seed_from(2);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        let q: Vec<f32> = xs.iter().map(|x| (x * 8.0).round() / 8.0).collect();
        let kl_lo = quantization_kl(&xs, &q, 20);
        let kl_hi = quantization_kl(&xs, &q, 500);
        // finer binning detects more information loss
        assert!(kl_hi > kl_lo, "hi {kl_hi} lo {kl_lo}");
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(quantization_kl(&[], &[], 50), 0.0);
        let xs = vec![1.0f32; 100];
        assert!(quantization_kl(&xs, &xs, 50) < 1e-12);
        let with_nan = vec![f32::NAN, 1.0];
        assert!(quantization_kl(&with_nan, &with_nan, 10).is_infinite());
    }

    #[test]
    fn histogram_binning_edges() {
        let h = Histogram::from_slice(&[0.0, 0.5, 1.0], 0.0, 1.0, 2);
        assert_eq!(h.total, 3);
        assert_eq!(h.counts[0], 1); // 0.0
        assert_eq!(h.counts[1], 2); // 0.5 (lands on the boundary) and 1.0 (clamped)
        // outside-range values clamp to edge bins
        let mut h2 = Histogram::new(0.0, 1.0, 4);
        h2.add(-5.0);
        h2.add(5.0);
        assert_eq!(h2.counts[0], 1);
        assert_eq!(h2.counts[3], 1);
    }
}
