//! Empirical distribution + discrete Kullback–Leibler divergence.
//!
//! The PushDown operation (sec. 3.3) interprets a precision switch as a
//! change of encoding and measures the information lost via KL(P || Q)
//! where Q is the distribution of the float32 master weights and P the
//! distribution of their quantized counterparts, both discretised by
//! equal-width binning at resolution r^l (eq. 1, 2).

/// Equal-width histogram over [lo, hi] with `bins` buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub total: u64,
}

/// Pad a degenerate (hi <= lo) range open on the right. The pad must be
/// RELATIVE to the magnitude: an absolute `lo + 1e-12` underflows back to
/// `lo` in f32 for |lo| ≳ 1e-4, yielding a zero-width histogram whose bin
/// math is 0/0 = NaN whenever a tensor is constant.
#[inline]
fn padded_range(lo: f32, hi: f32) -> (f32, f32) {
    if hi > lo {
        (lo, hi)
    } else {
        (lo, lo + lo.abs().max(1.0) * f32::EPSILON)
    }
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(bins > 0);
        let (lo, hi) = padded_range(lo, hi);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    /// Re-initialise in place for a new range/resolution without giving up
    /// the counts allocation (the PushDown scratch reuses one candidate
    /// histogram across every bisection eval of every layer).
    pub fn reset(&mut self, lo: f32, hi: f32, bins: usize) {
        assert!(bins > 0);
        let (lo, hi) = padded_range(lo, hi);
        self.lo = lo;
        self.hi = hi;
        self.counts.clear();
        self.counts.resize(bins, 0);
        self.total = 0;
    }

    #[inline]
    pub fn bin_of(&self, x: f32) -> usize {
        let b = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * b as f32) as isize;
        t.clamp(0, b as isize - 1) as usize
    }

    #[inline]
    pub fn add(&mut self, x: f32) {
        let i = self.bin_of(x);
        self.counts[i] += 1;
        self.total += 1;
    }

    pub fn from_slice(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    /// Probability of bin i with epsilon flooring (so KL stays finite when a
    /// bin is empty on one side only — the "information was created" case is
    /// penalised heavily but finitely).
    #[inline]
    pub fn prob(&self, i: usize, eps: f64) -> f64 {
        (self.counts[i] as f64 + eps) / (self.total as f64 + eps * self.counts.len() as f64)
    }
}

/// Discrete KL(P || Q) over two histograms with identical binning (eq. 2).
/// Returns bits (log base 2) — "the average number of bits lost through
/// changing the encoding".
pub fn kl_divergence(p: &Histogram, q: &Histogram, eps: f64) -> f64 {
    assert_eq!(p.counts.len(), q.counts.len());
    let mut kl = 0.0;
    for i in 0..p.counts.len() {
        let pi = p.prob(i, eps);
        let qi = q.prob(i, eps);
        if pi > 0.0 {
            kl += pi * (pi / qi).log2();
        }
    }
    kl.max(0.0)
}

/// KL between the EDF of `original` and of `quantized` at resolution `bins`,
/// binned over the ORIGINAL tensor's range (the encoding being abandoned).
pub fn quantization_kl(original: &[f32], quantized: &[f32], bins: usize) -> f64 {
    if original.is_empty() {
        return 0.0;
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in original {
        if !x.is_finite() {
            return f64::INFINITY;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let q = Histogram::from_slice(original, lo, hi, bins);
    let p = Histogram::from_slice(quantized, lo, hi, bins);
    kl_divergence(&p, &q, 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_distributions_zero_kl() {
        let mut r = Rng::seed_from(0);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        let kl = quantization_kl(&xs, &xs, 100);
        assert!(kl.abs() < 1e-9, "{kl}");
    }

    #[test]
    fn kl_nonnegative_and_sensitive() {
        let mut r = Rng::seed_from(1);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        // coarse quantization -> mass moves between bins -> positive KL
        let coarse: Vec<f32> = xs.iter().map(|x| (x * 2.0).round() / 2.0).collect();
        let fine: Vec<f32> = xs.iter().map(|x| (x * 4096.0).round() / 4096.0).collect();
        let kl_c = quantization_kl(&xs, &coarse, 100);
        let kl_f = quantization_kl(&xs, &fine, 100);
        assert!(kl_c > 0.0);
        assert!(kl_f < kl_c, "fine {kl_f} should lose less than coarse {kl_c}");
    }

    #[test]
    fn resolution_controls_sensitivity() {
        let mut r = Rng::seed_from(2);
        let xs: Vec<f32> = (0..5000).map(|_| r.normal() as f32).collect();
        let q: Vec<f32> = xs.iter().map(|x| (x * 8.0).round() / 8.0).collect();
        let kl_lo = quantization_kl(&xs, &q, 20);
        let kl_hi = quantization_kl(&xs, &q, 500);
        // finer binning detects more information loss
        assert!(kl_hi > kl_lo, "hi {kl_hi} lo {kl_lo}");
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(quantization_kl(&[], &[], 50), 0.0);
        let xs = vec![1.0f32; 100];
        assert!(quantization_kl(&xs, &xs, 50) < 1e-12);
        let with_nan = vec![f32::NAN, 1.0];
        assert!(quantization_kl(&with_nan, &with_nan, 10).is_infinite());
    }

    #[test]
    fn degenerate_range_pads_relative_to_magnitude() {
        // the old absolute 1e-12 pad underflowed to lo for |lo| >= ~1e-4
        for &lo in &[0.0f32, 0.25, -0.25, 1.0, -3.5, 1234.5, -1e6, 3e7] {
            let h = Histogram::new(lo, lo, 8);
            assert!(h.hi > h.lo, "zero-width histogram at lo={lo}");
            // a constant tensor must bin cleanly (no NaN bin math)
            let hc = Histogram::from_slice(&[lo; 64], lo, lo, 8);
            assert_eq!(hc.total, 64);
            assert_eq!(hc.counts.iter().sum::<u64>(), 64);
        }
    }

    #[test]
    fn constant_tensor_kl_is_finite() {
        // regression: <constant 0.25> used to produce a zero-width histogram
        let xs = vec![0.25f32; 500];
        let kl = quantization_kl(&xs, &xs, 100);
        assert!(kl.is_finite());
        assert!(kl.abs() < 1e-9, "{kl}");
        let ys = vec![-1234.5f32; 500];
        assert!(quantization_kl(&ys, &ys, 100) < 1e-9);
    }

    #[test]
    fn reset_reuses_allocation_and_matches_new() {
        let mut h = Histogram::new(0.0, 1.0, 64);
        for i in 0..64 {
            h.add(i as f32 / 64.0);
        }
        let cap = h.counts.capacity();
        h.reset(-2.0, 3.0, 32);
        assert_eq!(h.counts.capacity(), cap, "reset must not reallocate");
        assert_eq!(h.total, 0);
        assert!(h.counts.iter().all(|&c| c == 0));
        let fresh = Histogram::new(-2.0, 3.0, 32);
        assert_eq!((h.lo, h.hi, h.counts.len()), (fresh.lo, fresh.hi, 32));
    }

    #[test]
    fn histogram_binning_edges() {
        let h = Histogram::from_slice(&[0.0, 0.5, 1.0], 0.0, 1.0, 2);
        assert_eq!(h.total, 3);
        assert_eq!(h.counts[0], 1); // 0.0
        assert_eq!(h.counts[1], 2); // 0.5 (lands on the boundary) and 1.0 (clamped)
        // outside-range values clamp to edge bins
        let mut h2 = Histogram::new(0.0, 1.0, 4);
        h2.add(-5.0);
        h2.add(5.0);
        assert_eq!(h2.counts[0], 1);
        assert_eq!(h2.counts[3], 1);
    }
}
