//! Fixed-point arithmetic substrate (host side, mirrors the L1 kernels).

pub mod format;
pub mod histogram;
pub mod quantize;
pub mod sparse;

pub use format::FixedPointFormat;
pub use histogram::{kl_divergence, quantization_kl, Histogram};
pub use quantize::{
    max_abs, quantize_bin, quantize_nr_into, quantize_nr_slice, quantize_sr_into,
    quantize_sr_slice, zero_fraction,
};
pub use sparse::SparseFixedTensor;
