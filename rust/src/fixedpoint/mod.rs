//! Fixed-point arithmetic substrate (host side, mirrors the L1 kernels).
//!
//! * [`format`] — the `<WL, FL>` signed fixed-point format (sec. 2.1) with
//!   nearest (round-half-even) and stochastic rounding, plus the
//!   magic-number RNE constants shared by the scalar and chunked kernels.
//! * [`histogram`] — equal-width empirical distributions and the discrete
//!   KL divergence of eq. 1/2.
//! * [`quantize`] — whole-tensor quantization, including the fused chunked
//!   [`quantize_bin`] kernel (quantize + bin + zero-count in one pass) that
//!   powers the PushDown engine.
//! * [`sparse`] — the CSR-ish deployment substrate for quantized sparse
//!   inference.
//! * [`value`] — the precision-generic storage trait ([`QuantValue`])
//!   behind the native backend's real i8/i16 integer GEMM panels.

pub mod format;
pub mod histogram;
pub mod quantize;
pub mod sparse;
pub mod value;

pub use format::FixedPointFormat;
pub use histogram::{kl_divergence, quantization_kl, Histogram};
pub use quantize::{
    max_abs, quantize_bin, quantize_bin_scalar, quantize_nr_count, quantize_nr_into,
    quantize_nr_slice, quantize_nr_ste, quantize_sr_into, quantize_sr_slice, zero_fraction,
    QUANTIZE_LANES,
};
pub use sparse::SparseFixedTensor;
pub use value::QuantValue;
