//! Host-side tensor quantization (mirrors the L1 Pallas kernels).
//!
//! Used by PushDown candidate evaluation (quantize-then-KL during bisection)
//! and by the sparse inference path. Semantics match
//! `python/compile/kernels/fixedpoint.py` exactly; the parity is asserted by
//! `rust/tests/parity.rs` against the compiled artifacts.
//!
//! The hot path is the fused, chunked [`quantize_bin`] kernel, which
//! quantizes, histogram-bins and zero-counts a tensor in one pass:
//!
//! ```
//! use adapt::fixedpoint::{quantize_bin, FixedPointFormat, Histogram};
//!
//! let xs = [0.0f32, 0.26, -0.7, 0.02];
//! let mut hist = Histogram::new(-1.0, 1.0, 8);
//! let zeros = quantize_bin(&xs, FixedPointFormat::new(8, 4), &mut hist);
//! assert_eq!(hist.total, 4);
//! assert_eq!(zeros, 2); // 0.0 and 0.02 both snap to zero on the 1/16 grid
//! ```

use super::format::{
    round_half_even, round_half_even_fast, FixedPointFormat, RNE_FAST_LIMIT, RNE_MAGIC,
};
use super::histogram::Histogram;
use crate::util::rng::Rng;

/// Nearest-rounding quantize of a whole tensor (deterministic).
pub fn quantize_nr_slice(xs: &[f32], fmt: FixedPointFormat) -> Vec<f32> {
    let mut out = Vec::new();
    quantize_nr_into(xs, fmt, &mut out);
    out
}

/// In-place nearest-rounding quantize into a reusable buffer (avoids an
/// allocation per call; the naive-reference PushDown path uses this).
pub fn quantize_nr_into(xs: &[f32], fmt: FixedPointFormat, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fmt.quantize_nr(x)));
}

/// Lane width of the chunked [`quantize_bin`] kernel: wide enough to fill
/// full SIMD registers at any common vector width (SSE2 f32x4 through
/// AVX-512 f32x16) once the autovectorizer unrolls the quantize phase.
pub const QUANTIZE_LANES: usize = 16;

/// Fused quantize + histogram-bin + zero-count: the single-pass kernel of
/// the PushDown engine. Returns the number of quantized values that are
/// exactly zero (the complement of the paper's sp in eq. 8/9), measured in
/// the same pass — the quantized tensor is never materialized and no extra
/// scan is needed for the sparsity statistic.
///
/// # SIMD-friendly structure
///
/// The kernel walks the tensor in [`QUANTIZE_LANES`]-wide chunks. Phase A
/// quantizes a whole chunk into a stack lane buffer with straight-line,
/// branch-free arithmetic — `x * scale`, the magic-number round-to-nearest-
/// even (`(s + RNE_MAGIC) - RNE_MAGIC`), a two-sided clamp and the rescale —
/// which LLVM autovectorizes (verified via `cargo bench --bench micro`:
/// chunked-vs-scalar medians land in `BENCH_pushdown.json`). The magic
/// trick is only exact for |s| < [`RNE_FAST_LIMIT`]; phase A also reduces an
/// `all_fast` lane mask, and the rare chunk containing a larger (or
/// non-finite) scaled value gets those lanes recomputed through the scalar
/// [`round_half_even`] fallback, preserving bit-parity. Phase B then bins
/// the lane buffer — a scatter, inherently scalar, but operating on
/// register/L1-resident values.
///
/// Count-exact with the naive two-pass `quantize_nr_into` +
/// `Histogram::from_slice` for every input (the bin index is computed by the
/// same `Histogram::bin_of`, and the quantize agrees element-wise with
/// `FixedPointFormat::quantize_nr` up to the sign of zero; NaNs follow the
/// same saturating-cast path into bin 0 on both sides). Bit-parity with the
/// kept scalar reference [`quantize_bin_scalar`] is gated by the property
/// tests in `rust/tests/quant_fused_parallel.rs`.
pub fn quantize_bin(xs: &[f32], fmt: FixedPointFormat, hist: &mut Histogram) -> u64 {
    let scale = fmt.scale();
    let inv_scale = 1.0 / scale;
    let qmin = fmt.qmin();
    let qmax = fmt.qmax();
    let mut zeros = 0u64;
    let mut lane = [0.0f32; QUANTIZE_LANES];
    let mut chunks = xs.chunks_exact(QUANTIZE_LANES);
    for chunk in &mut chunks {
        // Phase A: branch-free quantize of the whole chunk (vectorizable).
        let mut all_fast = true;
        for (q, &x) in lane.iter_mut().zip(chunk) {
            let s = x * scale;
            let r = (s + RNE_MAGIC) - RNE_MAGIC;
            all_fast &= s.abs() < RNE_FAST_LIMIT; // false for NaN too
            *q = r.clamp(qmin, qmax) * inv_scale;
        }
        if !all_fast {
            // Rare: huge scaled values (or NaN/inf) where the magic-number
            // rounding is invalid — redo exactly those lanes via the scalar
            // reference so the result stays bit-identical to quantize_nr.
            for (q, &x) in lane.iter_mut().zip(chunk) {
                let s = x * scale;
                if !(s.abs() < RNE_FAST_LIMIT) {
                    *q = round_half_even(s).clamp(qmin, qmax) * inv_scale;
                }
            }
        }
        // Phase B: scalar scatter of the lane buffer into the histogram.
        for &q in &lane {
            zeros += u64::from(q == 0.0);
            let i = hist.bin_of(q);
            hist.counts[i] += 1;
        }
    }
    for &x in chunks.remainder() {
        let q = round_half_even_fast(x * scale).clamp(qmin, qmax) * inv_scale;
        zeros += u64::from(q == 0.0);
        let i = hist.bin_of(q);
        hist.counts[i] += 1;
    }
    hist.total += xs.len() as u64;
    zeros
}

/// The pre-chunking scalar fused kernel (PR 1): one element at a time
/// through [`round_half_even_fast`]. Kept as the bit-parity reference for
/// the chunked [`quantize_bin`] and as the "before" side of the
/// chunked-vs-scalar comparison in `benches/micro.rs`.
pub fn quantize_bin_scalar(xs: &[f32], fmt: FixedPointFormat, hist: &mut Histogram) -> u64 {
    let scale = fmt.scale();
    let inv_scale = 1.0 / scale;
    let qmin = fmt.qmin();
    let qmax = fmt.qmax();
    let mut zeros = 0u64;
    for &x in xs {
        let q = round_half_even_fast(x * scale).clamp(qmin, qmax) * inv_scale;
        zeros += u64::from(q == 0.0);
        let i = hist.bin_of(q);
        hist.counts[i] += 1;
    }
    hist.total += xs.len() as u64;
    zeros
}

/// Fused nearest-rounding fake-quant + clipped-STE mask + zero count — the
/// training quantizer of the native CPU backend (`runtime::native`). One
/// pass computes, per element:
///
/// * `q[i]` — the quantized value, bit-identical to [`quantize_bin_scalar`]'s
///   quantization (`round_half_even_fast(x·s)`, two-sided clamp, rescale by
///   the exact reciprocal of the power-of-two scale);
/// * `mask[i]` — the clipped straight-through-estimator gradient mask of the
///   L1 kernels (`python/compile/kernels/fixedpoint.py`): 1.0 where `x·s`
///   lies inside `[qmin, qmax]`, 0.0 where the value was clamped away (or is
///   NaN, which fails both comparisons);
/// * the returned count of exact zeros (complement of the paper's sp).
///
/// `scale` must be a positive power of two (every `<WL, FL>` grid satisfies
/// this, as do MuPPET's block-floating-point scales), so `* (1/scale)` and
/// `/ scale` agree bit-for-bit. `q` and `mask` must match `xs` in length.
pub fn quantize_nr_ste(
    xs: &[f32],
    scale: f32,
    qmin: f32,
    qmax: f32,
    q: &mut [f32],
    mask: &mut [f32],
) -> u64 {
    assert_eq!(xs.len(), q.len(), "quantize_nr_ste: q length");
    assert_eq!(xs.len(), mask.len(), "quantize_nr_ste: mask length");
    let inv_scale = 1.0 / scale;
    let mut zeros = 0u64;
    for ((qv, mv), &x) in q.iter_mut().zip(mask.iter_mut()).zip(xs) {
        let s = x * scale;
        let r = round_half_even_fast(s).clamp(qmin, qmax) * inv_scale;
        *qv = r;
        zeros += u64::from(r == 0.0);
        *mv = if s >= qmin && s <= qmax { 1.0 } else { 0.0 };
    }
    zeros
}

/// The mask-free sibling of [`quantize_nr_ste`] for forward-only passes
/// (the native backend's inference path): identical quantization and zero
/// count, no STE mask to allocate or fill.
pub fn quantize_nr_count(xs: &[f32], scale: f32, qmin: f32, qmax: f32, q: &mut [f32]) -> u64 {
    assert_eq!(xs.len(), q.len(), "quantize_nr_count: q length");
    let inv_scale = 1.0 / scale;
    let mut zeros = 0u64;
    for (qv, &x) in q.iter_mut().zip(xs) {
        let r = round_half_even_fast(x * scale).clamp(qmin, qmax) * inv_scale;
        *qv = r;
        zeros += u64::from(r == 0.0);
    }
    zeros
}

/// Stochastic-rounding quantize with noise from `rng`.
pub fn quantize_sr_slice(xs: &[f32], fmt: FixedPointFormat, rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::new();
    quantize_sr_into(xs, fmt, rng, &mut out);
    out
}

/// In-place stochastic-rounding quantize into a reusable buffer — the SR
/// twin of [`quantize_nr_into`], used by the sparse deployment export so
/// repeated per-layer exports stay allocation-free.
pub fn quantize_sr_into(xs: &[f32], fmt: FixedPointFormat, rng: &mut Rng, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fmt.quantize_sr(x, rng.uniform() as f32)));
}

/// Fraction of exact zeros (the paper's sparsity; sp in eq. 8/9 is the
/// complementary non-zero fraction).
pub fn zero_fraction(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let zeros = xs.iter().filter(|&&x| x == 0.0).count();
    zeros as f32 / xs.len() as f32
}

pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nr_slice_matches_scalar() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.1, -0.37, 5.0, -100.0, 0.0];
        let q = quantize_nr_slice(&xs, fmt);
        for (x, qq) in xs.iter().zip(&q) {
            assert_eq!(*qq, fmt.quantize_nr(*x));
        }
    }

    #[test]
    fn sr_unbiased() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.3f32; 50000]; // between grid points 4/16 and 5/16
        let mut rng = Rng::seed_from(9);
        let q = quantize_sr_slice(&xs, fmt, &mut rng);
        let mean: f32 = q.iter().sum::<f32>() / q.len() as f32;
        assert!((mean - 0.3).abs() < 2e-3, "{mean}");
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(zero_fraction(&[]), 0.0);
    }

    #[test]
    fn small_values_snap_to_zero() {
        // <8,4>: ULP = 1/16; values below 1/32 round to zero -> sparsity
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.01f32, -0.02, 0.03, 0.5];
        let q = quantize_nr_slice(&xs, fmt);
        assert_eq!(zero_fraction(&q), 0.75);
    }

    #[test]
    fn quantize_into_reuses_buffer() {
        let fmt = FixedPointFormat::new(6, 2);
        let xs = vec![1.3f32; 100];
        let mut buf = Vec::new();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.len(), 100);
        let cap = buf.capacity();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn sr_into_matches_slice_and_reuses_buffer() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs: Vec<f32> = (0..300).map(|i| 0.01 * i as f32 - 1.5).collect();
        let mut a = Rng::seed_from(21);
        let mut b = Rng::seed_from(21);
        let via_slice = quantize_sr_slice(&xs, fmt, &mut a);
        let mut buf = Vec::new();
        quantize_sr_into(&xs, fmt, &mut b, &mut buf);
        assert_eq!(via_slice, buf, "same rng stream must give same values");
        let cap = buf.capacity();
        quantize_sr_into(&xs, fmt, &mut b, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn fused_quantize_bin_matches_naive_two_pass() {
        use crate::fixedpoint::histogram::Histogram;
        let mut r = Rng::seed_from(5);
        let xs: Vec<f32> = (0..4096).map(|_| (r.normal() * 0.3) as f32).collect();
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in &xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let mut buf = Vec::new();
        for (wl, fl) in [(2u8, 1u8), (4, 2), (6, 3), (8, 4), (12, 8), (16, 10), (24, 12)] {
            let fmt = FixedPointFormat::new(wl, fl);
            quantize_nr_into(&xs, fmt, &mut buf);
            let naive = Histogram::from_slice(&buf, lo, hi, 100);
            let mut fused = Histogram::new(lo, hi, 100);
            let zeros = quantize_bin(&xs, fmt, &mut fused);
            assert_eq!(naive.counts, fused.counts, "<{wl},{fl}>");
            assert_eq!(naive.total, fused.total);
            // the ridden-along zero count equals a recount of the quantized buffer
            let recount = buf.iter().filter(|&&q| q == 0.0).count() as u64;
            assert_eq!(zeros, recount, "<{wl},{fl}>");
        }
    }

    #[test]
    fn chunked_matches_scalar_reference_bit_for_bit() {
        let mut r = Rng::seed_from(77);
        // lengths straddling the lane width, incl. remainder-only tensors
        for n in [0usize, 1, 7, 15, 16, 17, 31, 33, 1000, 4096 + 5] {
            let mut xs: Vec<f32> = (0..n).map(|_| (r.normal() * 0.4) as f32).collect();
            // salt with values that force the slow rounding path and NaN bins
            if n >= 16 {
                xs[3] = 1e9;
                xs[5] = -1e9;
                xs[9] = f32::NAN;
                xs[12] = f32::INFINITY;
            }
            let (lo, hi) = (-2.0f32, 2.0f32);
            for (wl, fl) in [(4u8, 2u8), (8, 4), (16, 10), (32, 16)] {
                let fmt = FixedPointFormat::new(wl, fl);
                let mut a = Histogram::new(lo, hi, 64);
                let mut b = Histogram::new(lo, hi, 64);
                let za = quantize_bin(&xs, fmt, &mut a);
                let zb = quantize_bin_scalar(&xs, fmt, &mut b);
                assert_eq!(a.counts, b.counts, "n={n} <{wl},{fl}>");
                assert_eq!(a.total, b.total);
                assert_eq!(za, zb, "n={n} <{wl},{fl}>");
            }
        }
    }

    #[test]
    fn nr_ste_matches_format_quantizer_and_masks_clamped() {
        let mut r = Rng::seed_from(31);
        let mut xs: Vec<f32> = (0..513).map(|_| (r.normal() * 2.0) as f32).collect();
        xs.extend_from_slice(&[0.0, -0.0, 100.0, -100.0, 1e9, -1e9, f32::NAN]);
        for (wl, fl) in [(4u8, 2u8), (6, 3), (8, 4), (16, 10), (32, 16)] {
            let fmt = FixedPointFormat::new(wl, fl);
            let mut q = vec![0.0f32; xs.len()];
            let mut m = vec![0.0f32; xs.len()];
            let zeros = quantize_nr_ste(&xs, fmt.scale(), fmt.qmin(), fmt.qmax(), &mut q, &mut m);
            let mut recount = 0u64;
            for (i, &x) in xs.iter().enumerate() {
                if x.is_nan() {
                    assert!(q[i].is_nan());
                    assert_eq!(m[i], 0.0, "NaN must be masked out of the gradient");
                    continue;
                }
                assert_eq!(q[i], fmt.quantize_nr(x), "<{wl},{fl}> x={x}");
                let s = x * fmt.scale();
                let inside = s >= fmt.qmin() && s <= fmt.qmax();
                assert_eq!(m[i], if inside { 1.0 } else { 0.0 }, "<{wl},{fl}> x={x}");
                recount += u64::from(q[i] == 0.0);
            }
            assert_eq!(zeros, recount, "<{wl},{fl}>");
            // and the zero count agrees with the fused PushDown kernel's
            let mut hist = Histogram::new(-4.0, 4.0, 32);
            assert_eq!(zeros, quantize_bin_scalar(&xs, fmt, &mut hist), "<{wl},{fl}>");
            // the mask-free sibling produces identical values and count
            let mut q2 = vec![0.0f32; xs.len()];
            let zeros2 = quantize_nr_count(&xs, fmt.scale(), fmt.qmin(), fmt.qmax(), &mut q2);
            assert_eq!(zeros2, zeros, "<{wl},{fl}>");
            for (a, b) in q.iter().zip(&q2) {
                assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
            }
        }
    }

    #[test]
    fn fused_quantize_bin_handles_constant_and_extremes() {
        use crate::fixedpoint::histogram::Histogram;
        let fmt = FixedPointFormat::new(8, 4);
        // constant tensor: degenerate (padded) range, everything in bin 0
        let xs = vec![0.25f32; 128];
        let mut h = Histogram::new(0.25, 0.25, 10);
        let zeros = quantize_bin(&xs, fmt, &mut h);
        assert_eq!(h.total, 128);
        assert_eq!(h.counts[0], 128);
        assert_eq!(zeros, 0, "0.25 is on the <8,4> grid, not zero");
        // values far outside the format's range clamp, then bin at the edges
        let wild = vec![1e9f32, -1e9, 0.0];
        let mut hw = Histogram::new(-1e9, 1e9, 4);
        let zw = quantize_bin(&wild, fmt, &mut hw);
        assert_eq!(zw, 1);
        let mut buf = Vec::new();
        quantize_nr_into(&wild, fmt, &mut buf);
        let naive = Histogram::from_slice(&buf, -1e9, 1e9, 4);
        assert_eq!(naive.counts, hw.counts);
    }
}
