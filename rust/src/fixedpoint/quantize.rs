//! Host-side tensor quantization (mirrors the L1 Pallas kernels).
//!
//! Used by PushDown candidate evaluation (quantize-then-KL during bisection)
//! and by the sparse inference path. Semantics match
//! `python/compile/kernels/fixedpoint.py` exactly; the parity is asserted by
//! `rust/tests/parity.rs` against the compiled artifacts.

use super::format::FixedPointFormat;
use crate::util::rng::Rng;

/// Nearest-rounding quantize of a whole tensor (deterministic).
pub fn quantize_nr_slice(xs: &[f32], fmt: FixedPointFormat) -> Vec<f32> {
    xs.iter().map(|&x| fmt.quantize_nr(x)).collect()
}

/// In-place nearest-rounding quantize into a reusable buffer (hot path for
/// PushDown bisection: avoids an allocation per candidate format).
pub fn quantize_nr_into(xs: &[f32], fmt: FixedPointFormat, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fmt.quantize_nr(x)));
}

/// Stochastic-rounding quantize with noise from `rng`.
pub fn quantize_sr_slice(xs: &[f32], fmt: FixedPointFormat, rng: &mut Rng) -> Vec<f32> {
    xs.iter()
        .map(|&x| fmt.quantize_sr(x, rng.uniform() as f32))
        .collect()
}

/// Fraction of exact zeros (the paper's sparsity; sp in eq. 8/9 is the
/// complementary non-zero fraction).
pub fn zero_fraction(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let zeros = xs.iter().filter(|&&x| x == 0.0).count();
    zeros as f32 / xs.len() as f32
}

pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nr_slice_matches_scalar() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.1, -0.37, 5.0, -100.0, 0.0];
        let q = quantize_nr_slice(&xs, fmt);
        for (x, qq) in xs.iter().zip(&q) {
            assert_eq!(*qq, fmt.quantize_nr(*x));
        }
    }

    #[test]
    fn sr_unbiased() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.3f32; 50000]; // between grid points 4/16 and 5/16
        let mut rng = Rng::seed_from(9);
        let q = quantize_sr_slice(&xs, fmt, &mut rng);
        let mean: f32 = q.iter().sum::<f32>() / q.len() as f32;
        assert!((mean - 0.3).abs() < 2e-3, "{mean}");
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(zero_fraction(&[]), 0.0);
    }

    #[test]
    fn small_values_snap_to_zero() {
        // <8,4>: ULP = 1/16; values below 1/32 round to zero -> sparsity
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.01f32, -0.02, 0.03, 0.5];
        let q = quantize_nr_slice(&xs, fmt);
        assert_eq!(zero_fraction(&q), 0.75);
    }

    #[test]
    fn quantize_into_reuses_buffer() {
        let fmt = FixedPointFormat::new(6, 2);
        let xs = vec![1.3f32; 100];
        let mut buf = Vec::new();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.len(), 100);
        let cap = buf.capacity();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }
}
