//! Host-side tensor quantization (mirrors the L1 Pallas kernels).
//!
//! Used by PushDown candidate evaluation (quantize-then-KL during bisection)
//! and by the sparse inference path. Semantics match
//! `python/compile/kernels/fixedpoint.py` exactly; the parity is asserted by
//! `rust/tests/parity.rs` against the compiled artifacts.

use super::format::{round_half_even_fast, FixedPointFormat};
use super::histogram::Histogram;
use crate::util::rng::Rng;

/// Nearest-rounding quantize of a whole tensor (deterministic).
pub fn quantize_nr_slice(xs: &[f32], fmt: FixedPointFormat) -> Vec<f32> {
    let mut out = Vec::new();
    quantize_nr_into(xs, fmt, &mut out);
    out
}

/// In-place nearest-rounding quantize into a reusable buffer (avoids an
/// allocation per call; the naive-reference PushDown path uses this).
pub fn quantize_nr_into(xs: &[f32], fmt: FixedPointFormat, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fmt.quantize_nr(x)));
}

/// Fused quantize + histogram-bin: the single-pass kernel of the PushDown
/// engine. Each element is quantized in the integer domain (precomputed
/// `scale`/`inv_scale`, branch-light round-half-even, branchless clamp) and
/// its quantized value is binned straight into `hist` — the quantized tensor
/// is never materialized.
///
/// Count-exact with the naive two-pass `quantize_nr_into` +
/// `Histogram::from_slice` for every input (the bin index is computed by the
/// same `Histogram::bin_of`, and the integer-domain quantize equals
/// `FixedPointFormat::quantize_nr` element-wise; NaNs follow the same
/// saturating-cast path into bin 0 on both sides).
pub fn quantize_bin(xs: &[f32], fmt: FixedPointFormat, hist: &mut Histogram) {
    let scale = fmt.scale();
    let inv_scale = 1.0 / scale;
    let qmin = fmt.qmin();
    let qmax = fmt.qmax();
    for &x in xs {
        let q = round_half_even_fast(x * scale).clamp(qmin, qmax) * inv_scale;
        let i = hist.bin_of(q);
        hist.counts[i] += 1;
    }
    hist.total += xs.len() as u64;
}

/// Stochastic-rounding quantize with noise from `rng`.
pub fn quantize_sr_slice(xs: &[f32], fmt: FixedPointFormat, rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::new();
    quantize_sr_into(xs, fmt, rng, &mut out);
    out
}

/// In-place stochastic-rounding quantize into a reusable buffer — the SR
/// twin of [`quantize_nr_into`], used by the sparse deployment export so
/// repeated per-layer exports stay allocation-free.
pub fn quantize_sr_into(xs: &[f32], fmt: FixedPointFormat, rng: &mut Rng, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fmt.quantize_sr(x, rng.uniform() as f32)));
}

/// Fraction of exact zeros (the paper's sparsity; sp in eq. 8/9 is the
/// complementary non-zero fraction).
pub fn zero_fraction(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let zeros = xs.iter().filter(|&&x| x == 0.0).count();
    zeros as f32 / xs.len() as f32
}

pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nr_slice_matches_scalar() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.1, -0.37, 5.0, -100.0, 0.0];
        let q = quantize_nr_slice(&xs, fmt);
        for (x, qq) in xs.iter().zip(&q) {
            assert_eq!(*qq, fmt.quantize_nr(*x));
        }
    }

    #[test]
    fn sr_unbiased() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.3f32; 50000]; // between grid points 4/16 and 5/16
        let mut rng = Rng::seed_from(9);
        let q = quantize_sr_slice(&xs, fmt, &mut rng);
        let mean: f32 = q.iter().sum::<f32>() / q.len() as f32;
        assert!((mean - 0.3).abs() < 2e-3, "{mean}");
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(zero_fraction(&[]), 0.0);
    }

    #[test]
    fn small_values_snap_to_zero() {
        // <8,4>: ULP = 1/16; values below 1/32 round to zero -> sparsity
        let fmt = FixedPointFormat::new(8, 4);
        let xs = vec![0.01f32, -0.02, 0.03, 0.5];
        let q = quantize_nr_slice(&xs, fmt);
        assert_eq!(zero_fraction(&q), 0.75);
    }

    #[test]
    fn quantize_into_reuses_buffer() {
        let fmt = FixedPointFormat::new(6, 2);
        let xs = vec![1.3f32; 100];
        let mut buf = Vec::new();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.len(), 100);
        let cap = buf.capacity();
        quantize_nr_into(&xs, fmt, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn sr_into_matches_slice_and_reuses_buffer() {
        let fmt = FixedPointFormat::new(8, 4);
        let xs: Vec<f32> = (0..300).map(|i| 0.01 * i as f32 - 1.5).collect();
        let mut a = Rng::seed_from(21);
        let mut b = Rng::seed_from(21);
        let via_slice = quantize_sr_slice(&xs, fmt, &mut a);
        let mut buf = Vec::new();
        quantize_sr_into(&xs, fmt, &mut b, &mut buf);
        assert_eq!(via_slice, buf, "same rng stream must give same values");
        let cap = buf.capacity();
        quantize_sr_into(&xs, fmt, &mut b, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn fused_quantize_bin_matches_naive_two_pass() {
        use crate::fixedpoint::histogram::Histogram;
        let mut r = Rng::seed_from(5);
        let xs: Vec<f32> = (0..4096).map(|_| (r.normal() * 0.3) as f32).collect();
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in &xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let mut buf = Vec::new();
        for (wl, fl) in [(2u8, 1u8), (4, 2), (6, 3), (8, 4), (12, 8), (16, 10), (24, 12)] {
            let fmt = FixedPointFormat::new(wl, fl);
            quantize_nr_into(&xs, fmt, &mut buf);
            let naive = Histogram::from_slice(&buf, lo, hi, 100);
            let mut fused = Histogram::new(lo, hi, 100);
            quantize_bin(&xs, fmt, &mut fused);
            assert_eq!(naive.counts, fused.counts, "<{wl},{fl}>");
            assert_eq!(naive.total, fused.total);
        }
    }

    #[test]
    fn fused_quantize_bin_handles_constant_and_extremes() {
        use crate::fixedpoint::histogram::Histogram;
        let fmt = FixedPointFormat::new(8, 4);
        // constant tensor: degenerate (padded) range, everything in bin 0
        let xs = vec![0.25f32; 128];
        let mut h = Histogram::new(0.25, 0.25, 10);
        quantize_bin(&xs, fmt, &mut h);
        assert_eq!(h.total, 128);
        assert_eq!(h.counts[0], 128);
        // values far outside the format's range clamp, then bin at the edges
        let wild = vec![1e9f32, -1e9, 0.0];
        let mut hw = Histogram::new(-1e9, 1e9, 4);
        quantize_bin(&wild, fmt, &mut hw);
        let mut buf = Vec::new();
        quantize_nr_into(&wild, fmt, &mut buf);
        let naive = Histogram::from_slice(&buf, -1e9, 1e9, 4);
        assert_eq!(naive.counts, hw.counts);
    }
}
