"""Reference simulation of the Rust native backend (runtime/native).

Re-implements, operation for operation, the chain that produces the first
training-step CE values of the native-backend golden test
(rust/tests/native_backend.rs): the in-tree xoshiro256++ PRNG, the
SyntheticVision generator, TNVS initialization, the Batcher shuffle and the
native train step (NR fake-quant + STE, forward, softmax-CE, backward, ASGD
with gradient normalization) at the constant initial <8,4> format.

The first precision switch cannot fire before the 5th step (lookback lower
bound), so the first four CEs are exactly the constant-<8,4> trajectory and
this script regenerates the committed golden values:

    python3 python/tools/native_golden.py golden         # MLP golden
    python3 python/tools/native_golden.py lenet-golden   # conv/pool golden
    python3 python/tools/native_golden.py resnet-golden  # BN/branch golden

The lenet mode mirrors the conv interpreter (runtime/native/{conv,step}.rs)
on ``Manifest::synthetic_lenet``: im2col with ``(ky, kx, ci)`` tap order onto
the same ascending-k GEMM folds, fused bias+ReLU, strict-``>`` first-win
2x2 maxpool, col2im with the interpreter's ``(oy, ox, ky, kx)`` per-element
fold order, and backward through the recomputed pool argmax and the clipped
STE. It regenerates ``rust/tests/golden/lenet_native_ce.json``.

The resnet mode mirrors the batchnorm/downsample/global-avgpool lowerings
on ``Manifest::synthetic_resnet``: bias-free GEMMs into training-mode
batchnorm (serial row-ascending batch stats, running-average fold with
momentum 0.1), a linear strided 1x1 ``downsample`` branch whose successor
reads the same input slot, the pre-ReLU skip-adds, and the global average
pool feeding the dense head. It regenerates
``rust/tests/golden/resnet_native_ce.json``.

f32 arithmetic is mirrored with numpy float32 in the same operation order;
the only expected deviations from the Rust binary are 1-ULP differences in
libm transcendentals (sin/cos/exp/log), far below the golden tolerance.

    python3 python/tools/native_golden.py learncheck
    python3 python/tools/native_golden.py lenet-learncheck

run longer profiles without precision switching (constant <8,4> — a lower
bound on what AdaPT achieves, since switches only ever ADD precision) and
report the CE trend and held-out accuracy backing the e2e test thresholds.
"""

import math
import sys

import numpy as np

M64 = (1 << 64) - 1
F32 = np.float32


def _splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return x, z ^ (z >> 31)


def _rotl(v, k):
    return ((v << k) | (v >> (64 - k))) & M64


class Rng:
    """util/rng.rs: xoshiro256++ seeded via splitmix64."""

    def __init__(self, seed=None, state=None):
        if state is not None:
            self.s = list(state)
        else:
            s = []
            x = seed & M64
            for _ in range(4):
                x, z = _splitmix64(x)
                s.append(z)
            self.s = s
        self.cached_normal = None

    def fold(self, salt):
        x = self.s[0] ^ self.s[2] ^ ((salt * 0x9E3779B97F4A7C15) & M64)
        _, z = _splitmix64(x)
        return Rng(seed=z)

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()

    def below(self, n):
        while True:
            x = self.next_u64()
            m = x * n
            lo = m & M64
            if lo >= n:
                return m >> 64
            t = ((1 << 64) - n) % n
            if lo >= t:
                return m >> 64

    def normal(self):
        if self.cached_normal is not None:
            z = self.cached_normal
            self.cached_normal = None
            return z
        while True:
            u1 = self.uniform()
            if u1 <= 2.2250738585072014e-308:
                continue
            u2 = self.uniform()
            r = math.sqrt(-2.0 * math.log(u1))
            a = 2.0 * math.pi * u2
            s, c = math.sin(a), math.cos(a)
            self.cached_normal = r * s
            return r * c

    def truncated_normal(self, mu, sigma, a):
        if sigma == 0.0 or a == 0.0:
            return mu
        while True:
            z = self.normal() * sigma
            if abs(z) <= a:
                return mu + z

    def shuffle(self, v):
        for i in range(len(v) - 1, 0, -1):
            j = self.below(i + 1)
            v[i], v[j] = v[j], v[i]


def f32(x):
    return F32(x)


def seq_sum_f32(arr):
    acc = F32(0.0)
    for v in arr:
        acc = F32(acc + F32(v))
    return acc


PI32 = F32(np.float64(math.pi))  # std::f32::consts::PI == (f32)pi


class SyntheticVision:
    """data/synthetic.rs, f32 op order mirrored."""

    def __init__(self, h, w, c, classes, length, seed, noise):
        self.h, self.w, self.c = h, w, c
        self.classes = classes
        self.len = length
        self.seed = seed
        self.noise = F32(noise)
        self.max_shift = 3
        self.offset = 0
        base = Rng(seed=seed)
        self.templates = []
        for cls in range(classes):
            rng = base.fold(cls + 0x1000)
            n_blobs = 3 + rng.below(3)
            blobs = []
            for _ in range(n_blobs):
                cx = F32(rng.uniform_in(0.2, 0.8)) * F32(w)
                cy = F32(rng.uniform_in(0.2, 0.8)) * F32(h)
                sx = F32(rng.uniform_in(0.08, 0.25)) * F32(w)
                sy = F32(rng.uniform_in(0.08, 0.25)) * F32(h)
                theta = F32(rng.uniform_in(0.0, math.pi))
                amp = [F32(rng.uniform_in(-1.2, 1.2)) for _ in range(3)]
                blobs.append((cx, cy, sx, sy, theta, amp))
            fx = F32(rng.uniform_in(0.5, 3.0))
            fy = F32(rng.uniform_in(0.5, 3.0))
            phase = F32(rng.uniform_in(0.0, 6.28))
            gamp = F32(rng.uniform_in(0.1, 0.45))
            self.templates.append(
                self._render(h, w, c, blobs, (fx, fy, phase, gamp))
            )

    @staticmethod
    def _render(h, w, c, blobs, grating):
        fx, fy, phase, gamp = grating
        img = np.zeros(h * w * c, dtype=np.float32)
        for y in range(h):
            for x in range(w):
                arg = F32(
                    F32(PI32 * F32(2.0))
                    * F32(F32(F32(fx * F32(x)) / F32(w)) + F32(F32(fy * F32(y)) / F32(h)))
                    + phase
                )
                grate = F32(gamp * F32(math.sin(float(arg))))
                for ch in range(c):
                    v = grate
                    for (cx, cy, sx, sy, theta, amp) in blobs:
                        dx = F32(F32(x) - cx)
                        dy = F32(F32(y) - cy)
                        s = F32(math.sin(float(theta)))
                        co = F32(math.cos(float(theta)))
                        u = F32(F32(co * dx) + F32(s * dy))
                        t = F32(F32(-s) * dx + F32(co * dy))
                        us = F32(u / sx)
                        ts = F32(t / sy)
                        d = F32(F32(us * us) + F32(ts * ts))
                        e = F32(math.exp(float(F32(F32(-0.5) * d))))
                        v = F32(v + F32(amp[ch % 3] * e))
                    img[(y * w + x) * c + ch] = v
        n = F32(len(img))
        mean = F32(seq_sum_f32(img) / n)
        var = F32(seq_sum_f32([F32(F32(v - mean) * F32(v - mean)) for v in img]) / n)
        std = max(F32(math.sqrt(float(var))), F32(1e-6))
        return np.array([F32(F32(v - mean) / std) for v in img], dtype=np.float32)

    def heldout(self, offset, length):
        self.offset = offset
        self.len = length
        return self

    def fill(self, i):
        i = i + self.offset
        rng = Rng(seed=self.seed).fold(i + 0x90000000)
        cls = i % self.classes
        tpl = self.templates[cls]
        dx = rng.below(2 * self.max_shift + 1) - self.max_shift
        dy = rng.below(2 * self.max_shift + 1) - self.max_shift
        gain = F32(rng.uniform_in(0.8, 1.2))
        h, w, c = self.h, self.w, self.c
        out = np.zeros(h * w * c, dtype=np.float32)
        for y in range(h):
            for x in range(w):
                sy = min(max(y + dy, 0), h - 1)
                sx = min(max(x + dx, 0), w - 1)
                for ch in range(c):
                    t = tpl[(sy * w + sx) * c + ch]
                    noise = F32(F32(rng.normal()) * self.noise)
                    out[(y * w + x) * c + ch] = F32(F32(gain * t) + noise)
        return out, cls


def init_params(dims, seed):
    """init/mod.rs init_params for the synthetic_dense param layout."""
    base = Rng(seed=seed)
    params = []
    for li, (fi, fo) in enumerate(dims):
        i = 2 * li  # kernel param index
        rng = base.fold(i + 1)
        sigma = math.sqrt(1.0 / fi)
        a = math.sqrt(3.0 / fi)
        k = np.array(
            [F32(rng.truncated_normal(0.0, sigma, a)) for _ in range(fi * fo)],
            dtype=np.float32,
        ).reshape(fi, fo)
        params.append(k)
        params.append(np.zeros(fo, dtype=np.float32))
    return params


class Batcher:
    """data/loader.rs Batcher (the PrefetchLoader produces the same stream)."""

    def __init__(self, data, batch, seed):
        self.data = data
        self.batch = batch
        self.order = list(range(data.len))
        self.cursor = 0
        self.rng = Rng(seed=seed)
        self.rng.shuffle(self.order)

    def next_batch(self):
        n = self.data.len
        if self.cursor + self.batch > n:
            self.cursor = 0
            self.rng.shuffle(self.order)
        xs, ys = [], []
        for j in range(self.batch):
            i = self.order[(self.cursor + j) % n]
            x, y = self.data.fill(i)
            xs.append(x)
            ys.append(y)
        self.cursor += self.batch
        return np.stack(xs), np.array(ys, dtype=np.int64)


def quant_ste(x, scale, qmin, qmax):
    s = (x * F32(scale)).astype(np.float32)
    r = np.clip(np.rint(s), F32(qmin), F32(qmax)).astype(np.float32)
    q = (r * F32(1.0 / scale)).astype(np.float32)
    mask = ((s >= F32(qmin)) & (s <= F32(qmax))).astype(np.float32)
    return q, mask


def matmul_seq(a, b):
    """f32 matmul with k-ascending accumulation (matches ops::matmul)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        acc += np.outer(a[:, kk], b[kk, :]).astype(np.float32)
    return acc


def matmul_at_b_seq(a, g):
    """Aᵀ@G with m-ascending accumulation (matches ops::matmul_at_b)."""
    m, k = a.shape
    m2, n = g.shape
    assert m == m2
    acc = np.zeros((k, n), dtype=np.float32)
    for mm in range(m):
        acc += np.outer(a[mm, :], g[mm, :]).astype(np.float32)
    return acc


def matmul_a_bt_seq(g, w):
    """G@Wᵀ with n-ascending accumulation (matches ops::matmul_a_bt)."""
    m, n = g.shape
    k, n2 = w.shape
    assert n == n2
    acc = np.zeros((m, k), dtype=np.float32)
    for nn in range(n):
        acc += np.outer(g[:, nn], w[:, nn]).astype(np.float32)
    return acc


class Geom:
    """runtime/native/plan.rs ConvGeom.

    SAME output is ``ceil(i/s)`` with ``pad_total = max((o-1)s + k - i, 0)``
    split top/left = ``pad_total // 2`` (the extra row/col lands
    bottom/right — the JAX convention the AOT defs assume). ``relu=False``
    marks a linear ``downsample`` branch; ``residual_from=j`` adds layer
    j's quantized output before the ReLU."""

    def __init__(self, ih, iw, ci, k, co, padding, pool, stride=1,
                 pool_kind="max", relu=True, residual_from=None):
        self.ih, self.iw, self.ci, self.k, self.co = ih, iw, ci, k, co
        self.stride = stride
        if padding == "same":
            self.oh, self.ow = -(-ih // stride), -(-iw // stride)
            pad_h = max((self.oh - 1) * stride + k - ih, 0)
            pad_w = max((self.ow - 1) * stride + k - iw, 0)
            self.pad_top, self.pad_left = pad_h // 2, pad_w // 2
        else:  # valid
            self.oh, self.ow = (ih - k) // stride + 1, (iw - k) // stride + 1
            self.pad_top = self.pad_left = 0
        self.pool = pool
        self.pool_kind = pool_kind
        self.relu = relu
        self.residual_from = residual_from
        self.ph, self.pw = self.oh // pool, self.ow // pool
        self.di = k * k * ci  # im2col row length == GEMM depth
        self.in_elems = ih * iw * ci
        self.out_elems = self.ph * self.pw * co


def im2col(g, x):
    """conv.rs im2col: (b, ih*iw*ci) -> (b*oh*ow, kh*kw*ci), taps (ky,kx,ci).

    Pure gather (padded taps are exact 0.0), so vectorization is fold-free.
    """
    b = x.shape[0]
    s = g.stride
    xs = x.reshape(b, g.ih, g.iw, g.ci)
    pb = max((g.oh - 1) * s + g.k - g.ih - g.pad_top, 0)
    pr = max((g.ow - 1) * s + g.k - g.iw - g.pad_left, 0)
    xp = np.pad(xs, ((0, 0), (g.pad_top, pb), (g.pad_left, pr), (0, 0)))
    cols = np.empty((b, g.oh, g.ow, g.k, g.k, g.ci), dtype=np.float32)
    for ky in range(g.k):
        for kx in range(g.k):
            cols[:, :, :, ky, kx, :] = xp[
                :, ky : ky + (g.oh - 1) * s + 1 : s, kx : kx + (g.ow - 1) * s + 1 : s, :
            ]
    return cols.reshape(b * g.oh * g.ow, g.di)


def col2im(g, dcols, b):
    """conv.rs col2im: scatter-add back to (b, ih*iw*ci).

    Loop order (oy, ox) outer / (ky, kx) inner reproduces the interpreter's
    per-element accumulation order exactly (batch/channel lanes are disjoint).
    """
    dc = dcols.reshape(b, g.oh, g.ow, g.k, g.k, g.ci)
    dx = np.zeros((b, g.ih, g.iw, g.ci), dtype=np.float32)
    for oy in range(g.oh):
        for ox in range(g.ow):
            for ky in range(g.k):
                iy = oy * g.stride + ky - g.pad_top
                if iy < 0 or iy >= g.ih:
                    continue
                for kx in range(g.k):
                    ix = ox * g.stride + kx - g.pad_left
                    if 0 <= ix < g.iw:
                        dx[:, iy, ix, :] = (
                            dx[:, iy, ix, :] + dc[:, oy, ox, ky, kx, :]
                        ).astype(np.float32)
    return dx.reshape(b, g.in_elems)


def _pool_windows(g, z, b):
    """(b*oh*ow, co) -> (b, ph, pw, p*p, co) with the window axis in
    ascending (ky, kx) order — np.argmax's first-max then equals the
    interpreter's strict-> first-win scan."""
    p = g.pool
    w = z.reshape(b, g.ph, p, g.pw, p, g.co).transpose(0, 1, 3, 2, 4, 5)
    return w.reshape(b, g.ph, g.pw, p * p, g.co)


def maxpool_fwd(g, z, b):
    """conv.rs maxpool_forward on the (b*oh*ow, co) conv output."""
    win = _pool_windows(g, z, b)
    return win.max(axis=3).reshape(b, g.out_elems)


def maxpool_bwd(g, z, gpool, b):
    """conv.rs maxpool_backward: route to the recomputed first-win argmax."""
    win = _pool_windows(g, z, b)
    idx = np.argmax(win, axis=3)  # first occurrence of the max
    dwin = np.zeros_like(win)
    np.put_along_axis(dwin, idx[:, :, :, None, :], gpool.reshape(b, g.ph, g.pw, 1, g.co), axis=3)
    p = g.pool
    dwin = dwin.reshape(b, g.ph, g.pw, p, p, g.co).transpose(0, 1, 3, 2, 4, 5)
    return dwin.reshape(b * g.oh * g.ow, g.co)


def avgpool_fwd(g, z, b):
    """conv.rs avgpool_forward: zero-seeded ascending (ky,kx) sum fold,
    then one multiply by 1/p² (exact for the power-of-two windows)."""
    win = _pool_windows(g, z, b)
    inv = F32(1.0 / (g.pool * g.pool))
    acc = np.zeros((b, g.ph, g.pw, g.co), dtype=np.float32)
    for t in range(g.pool * g.pool):
        acc = (acc + win[:, :, :, t, :]).astype(np.float32)
    return (acc * inv).astype(np.float32).reshape(b, g.out_elems)


def avgpool_bwd(g, gpool, b):
    """conv.rs avgpool_backward: every window element receives g·(1/p²)."""
    p = g.pool
    inv = F32(1.0 / (p * p))
    gv = (gpool.reshape(b, g.ph, g.pw, 1, g.co) * inv).astype(np.float32)
    dwin = np.broadcast_to(gv, (b, g.ph, g.pw, p * p, g.co))
    dwin = dwin.reshape(b, g.ph, g.pw, p, p, g.co).transpose(0, 1, 3, 2, 4, 5)
    return np.ascontiguousarray(dwin).reshape(b * g.oh * g.ow, g.co)


BN_EPS = F32(1e-5)


def bn_fwd_train(z, gamma, beta):
    """ops.rs bn_forward_train: biased batch stats via two serial
    row-ascending passes, every op a separate f32 rounding.

    Returns (y, xhat, k, mean, var) — the transformed activations, the
    normalized pre-scale values and ``k = gamma·inv_std`` for backward,
    and the batch stats for the running-average fold."""
    rows = z.shape[0]
    inv_n = F32(1.0 / rows)
    mean = np.zeros(z.shape[1], dtype=np.float32)
    for r in range(rows):
        mean = (mean + z[r]).astype(np.float32)
    mean = (mean * inv_n).astype(np.float32)
    var = np.zeros(z.shape[1], dtype=np.float32)
    for r in range(rows):
        d = (z[r] - mean).astype(np.float32)
        var = (var + (d * d).astype(np.float32)).astype(np.float32)
    var = (var * inv_n).astype(np.float32)
    s = np.sqrt((var + BN_EPS).astype(np.float32)).astype(np.float32)
    inv_std = (F32(1.0) / s).astype(np.float32)
    k = (gamma * inv_std).astype(np.float32)
    xhat = ((z - mean).astype(np.float32) * inv_std).astype(np.float32)
    y = ((xhat * gamma).astype(np.float32) + beta).astype(np.float32)
    return y, xhat, k, mean, var


def bn_bwd(g, xhat, k):
    """ops.rs bn_backward: g enters as dL/dy, returns (dz, dgamma, dbeta).

    ``dz = (g - mean(g) - xhat·mean(g·xhat)) · k`` with the interpreter's
    exact fold order: serial row-ascending sums, then per-element
    ``t1 = g - c1; t2 = xhat·c2; dz = (t1 - t2)·k``."""
    rows = g.shape[0]
    inv_n = F32(1.0 / rows)
    sdy = np.zeros(g.shape[1], dtype=np.float32)
    sdyx = np.zeros(g.shape[1], dtype=np.float32)
    for r in range(rows):
        sdy = (sdy + g[r]).astype(np.float32)
        sdyx = (sdyx + (g[r] * xhat[r]).astype(np.float32)).astype(np.float32)
    c1 = (sdy * inv_n).astype(np.float32)
    c2 = (sdyx * inv_n).astype(np.float32)
    t1 = (g - c1).astype(np.float32)
    t2 = (xhat * c2).astype(np.float32)
    dz = ((t1 - t2).astype(np.float32) * k).astype(np.float32)
    return dz, sdyx, sdy


def bn_fold(w, gamma, beta, mean, var):
    """ops.rs bn_fold: W' = W·s, b' = beta − mean·s, s = gamma/sqrt(var+eps)."""
    inv = (F32(1.0) / np.sqrt((var + BN_EPS).astype(np.float32)).astype(np.float32)).astype(np.float32)
    s = (gamma * inv).astype(np.float32)
    wf = (w * s).astype(np.float32)
    bf = (beta - (mean * s).astype(np.float32)).astype(np.float32)
    return wf, bf


def native_step(params, gsum, x, y, fmt, enable, hyper, layers=None):
    """runtime/native/step.rs train step; fmt = (scale, qmin, qmax).

    ``layers`` lists one entry per layer: ``None`` for dense, a :class:`Geom`
    for conv (conv layers are always ReLU'd; pool sits between the ReLU and
    the activation quantizer, exactly as the interpreter orders them)."""
    lr, l1, l2, pen, gnorm = hyper
    L = len(params) // 2
    scale, qmin, qmax = fmt
    b = len(y)
    c = params[2 * (L - 1)].shape[1]
    if layers is None:
        layers = [None] * L

    wq, mask_w, sparsity = [], [], []
    for i in range(L):
        w = params[2 * i]
        if enable:
            q, mk = quant_ste(w, scale, qmin, qmax)
            zeros = int(np.count_nonzero(q == 0.0))
        else:
            q, mk = w.copy(), np.ones_like(w)
            zeros = int(np.count_nonzero(w == 0.0))
        wq.append(q)
        mask_w.append(mk)
        sparsity.append(F32(zeros) / F32(w.size))

    acts = [x.reshape(b, -1).astype(np.float32)]
    pre_q, mask_a, cols_of = [], [], []
    for i, g in enumerate(layers):
        if g is None:
            cols_of.append(None)
            z = matmul_seq(acts[i], wq[i])
            z = (z + params[2 * i + 1]).astype(np.float32)
            if i + 1 < L:
                z = np.maximum(z, F32(0.0))
            pre_quant = z
        else:
            cols = im2col(g, acts[i])
            cols_of.append(cols)
            z = matmul_seq(cols, wq[i])  # (b*oh*ow, co)
            z = (z + params[2 * i + 1]).astype(np.float32)
            z = np.maximum(z, F32(0.0))  # conv layers are always ReLU'd
            pre_quant = maxpool_fwd(g, z, b) if g.pool > 1 else z.reshape(b, -1)
        if enable:
            q, mk = quant_ste(pre_quant, scale, qmin, qmax)
        else:
            q, mk = pre_quant.copy(), np.ones_like(pre_quant)
        pre_q.append(z)
        mask_a.append(mk)
        acts.append(q.reshape(b, -1))

    logits = acts[L]
    g = np.zeros((b, c), dtype=np.float32)
    ce_sum = 0.0
    correct = 0
    inv_b = F32(1.0 / b)
    for r in range(b):
        row = logits[r]
        mx = F32(np.max(row))
        se = F32(0.0)
        for j in range(c):
            se = F32(se + F32(np.exp(F32(row[j] - mx))))
        lse = F32(mx + F32(np.log(se)))
        ce_sum += float(F32(lse - row[y[r]]))
        if int(np.argmax(row)) == y[r]:
            correct += 1
        for j in range(c):
            p = F32(np.exp(F32(row[j] - lse)))
            oh = F32(1.0) if j == y[r] else F32(0.0)
            g[r, j] = F32(F32(p - oh) * inv_b)
    ce = F32(ce_sum / b)
    acc = correct / b

    reg = F32(0.0)
    for i in range(L):
        w = params[2 * i].astype(np.float64)
        s1 = float(np.sum(np.abs(w)))
        s2 = float(np.sum(w * w))
        reg = F32(reg + F32(F32(F32(l1) * F32(s1)) + F32(F32(0.5) * F32(F32(l2) * F32(s2)))))
    # penalty (stop-gradient, enters the reported loss only)
    wl32 = F32(8.0 / 32.0) if enable else F32(32.0 / 32.0)
    penalty = F32(0.0)
    for i in range(L):
        penalty = F32(penalty + F32(F32(pen) * F32(wl32 * F32(F32(1.0) - sparsity[i]))))
    loss = F32(F32(ce + reg) + penalty)

    grad_norm = [None] * L
    gsum_norm = [None] * L
    for i in range(L - 1, -1, -1):
        geom = layers[i]
        g = (g.reshape(mask_a[i].shape) * mask_a[i]).astype(np.float32)
        if geom is None:
            if i + 1 < L:
                g = np.where(pre_q[i] > 0.0, g, F32(0.0)).astype(np.float32)
            gemm_in, g_full = acts[i], g
        else:
            if geom.pool > 1:
                g_full = maxpool_bwd(geom, pre_q[i], g, b)
            else:
                g_full = g.reshape(-1, geom.co).copy()
            g_full = np.where(pre_q[i] > 0.0, g_full, F32(0.0)).astype(np.float32)
            gemm_in = cols_of[i]
        db = np.zeros(g_full.shape[1], dtype=np.float32)
        for r in range(g_full.shape[0]):
            db = (db + g_full[r]).astype(np.float32)
        dw = matmul_at_b_seq(gemm_in, g_full)
        dw = (dw * mask_w[i]).astype(np.float32)
        w = params[2 * i]
        dw = (dw + (F32(l1) * np.sign(w) + F32(l2) * w).astype(np.float32)).astype(
            np.float32
        )
        if i > 0:
            g = matmul_a_bt_seq(g_full, wq[i])
            if geom is not None:
                g = col2im(geom, g, b)
        gn = F32(math.sqrt(float(np.sum(dw.astype(np.float64) ** 2))))
        grad_norm[i] = gn
        gsum[i] = (gsum[i] + dw).astype(np.float32)
        gsum_norm[i] = F32(math.sqrt(float(np.sum(gsum[i].astype(np.float64) ** 2))))
        denom = F32(gn + F32(1e-12))
        if gnorm:
            params[2 * i] = (w - F32(lr) * (dw / denom).astype(np.float32)).astype(
                np.float32
            )
        else:
            params[2 * i] = (w - F32(lr) * dw).astype(np.float32)
        params[2 * i + 1] = (params[2 * i + 1] - F32(lr) * db).astype(np.float32)
    return loss, ce, acc


def infer_accuracy(params, data, fmt, enable, batch, n_batches, layers=None):
    L = len(params) // 2
    scale, qmin, qmax = fmt
    if layers is None:
        layers = [None] * L
    wq = []
    for i in range(L):
        if enable:
            q, _ = quant_ste(params[2 * i], scale, qmin, qmax)
        else:
            q = params[2 * i]
        wq.append(q)
    accs = []
    for k in range(n_batches):
        xs, ys = [], []
        for j in range(batch):
            i = (k * batch + j) % data.len
            x, y = data.fill(i)
            xs.append(x)
            ys.append(y)
        h = np.stack(xs).reshape(batch, -1).astype(np.float32)
        for i, g in enumerate(layers):
            if g is None:
                z = matmul_seq(h, wq[i])
                z = (z + params[2 * i + 1]).astype(np.float32)
                if i + 1 < L:
                    z = np.maximum(z, F32(0.0))
            else:
                z = matmul_seq(im2col(g, h), wq[i])
                z = (z + params[2 * i + 1]).astype(np.float32)
                z = np.maximum(z, F32(0.0))
                z = maxpool_fwd(g, z, batch) if g.pool > 1 else z.reshape(batch, -1)
            if enable:
                h, _ = quant_ste(z, scale, qmin, qmax)
            else:
                h = z
            h = h.reshape(batch, -1)
        accs.append(float(np.mean(np.argmax(h, axis=1) == ys)))
    return sum(accs) / len(accs)


DIMS = [(64, 32), (32, 16), (16, 10)]
FMT_8_4 = (16.0, -128.0, 127.0)
HYPER = (0.05, 2e-4, 1e-4, 1e-3, True)  # lr, l1, l2, pen, gnorm
SEED = 42

# Manifest::synthetic_lenet("lenet-native", 16): 12x12x1 -> conv 5x5 SAME x6
# maxpool2 -> conv 5x5 VALID x16 -> flatten 64 -> 32 -> 16 -> 10. The 2-D
# kernel view is (kh*kw*ci, co), whose first dim IS the TNVS fan-in, so
# init_params works unchanged on these dims.
LENET_GEOMS = [
    Geom(12, 12, 1, 5, 6, "same", 2),
    Geom(6, 6, 6, 5, 16, "valid", 1),
    None,
    None,
    None,
]
LENET_DIMS = [(25, 6), (150, 16), (64, 32), (32, 16), (16, 10)]

# ---------------------------------------------------------------------------
# Manifest::synthetic_resnet("resnet-native", 16): 8x8x1 -> conv 3x3 SAME x8
# BN (stem) -> conv 3x3 x8 BN -> conv 3x3 x8 BN (+stem) -> [downsample 1x1
# s2 x16 BN] -> conv 3x3 s2 x16 BN -> conv 3x3 x16 BN (+downsample, global
# avgpool4) -> 1x1x16 -> flatten 16 -> 10. Params in manifest order are
# (kernel, gamma, beta) per BN conv then (kernel, bias) for the fc head;
# bn_state is (mean, var) per BN conv. The downsample branch (layer 3) is
# LINEAR (no ReLU) and its successor (layer 4) reads the SAME input slot.
# ---------------------------------------------------------------------------

RESNET_GEOMS = [
    Geom(8, 8, 1, 3, 8, "same", 1),
    Geom(8, 8, 8, 3, 8, "same", 1),
    Geom(8, 8, 8, 3, 8, "same", 1, residual_from=0),
    Geom(8, 8, 8, 1, 16, "same", 1, stride=2, relu=False),  # downsample branch
    Geom(8, 8, 8, 3, 16, "same", 1, stride=2),
    Geom(4, 4, 16, 3, 16, "same", 4, pool_kind="avg", residual_from=3),
    None,  # fc 16 -> 10
]
# (kernel, gamma, beta, mean, var, bias) param/bn indices per layer
RESNET_WIRING = [
    (0, 1, 2, 0, 1, None),
    (3, 4, 5, 2, 3, None),
    (6, 7, 8, 4, 5, None),
    (9, 10, 11, 6, 7, None),
    (12, 13, 14, 8, 9, None),
    (15, 16, 17, 10, 11, None),
    (18, None, None, None, None, 19),
]
# input slot per layer: a downsample successor reads the branch's own input
RESNET_SRC = [0, 1, 2, 3, 3, 5, 6]
RESNET_KDIMS = [(9, 8), (72, 8), (72, 8), (8, 16), (72, 16), (144, 16), (16, 10)]
RESNET_CHANNELS = [8, 8, 8, 16, 16, 16]


def init_params_resnet(seed):
    """init/mod.rs init_params on the synthetic_resnet param layout: the
    fold salt is the ACTUAL manifest param index + 1 (kernels sit at
    0,3,6,9,12,15,18), gammas are ones, betas/biases zeros."""
    base = Rng(seed=seed)
    params = []
    for li, (fi, fo) in enumerate(RESNET_KDIMS):
        ki = RESNET_WIRING[li][0]
        rng = base.fold(ki + 1)
        sigma = math.sqrt(1.0 / fi)
        a = math.sqrt(3.0 / fi)
        k = np.array(
            [F32(rng.truncated_normal(0.0, sigma, a)) for _ in range(fi * fo)],
            dtype=np.float32,
        ).reshape(fi, fo)
        params.append(k)
        if RESNET_WIRING[li][1] is not None:
            co = RESNET_CHANNELS[li]
            params.append(np.ones(co, dtype=np.float32))  # gamma
            params.append(np.zeros(co, dtype=np.float32))  # beta
        else:
            params.append(np.zeros(fo, dtype=np.float32))  # fc bias
    return params


def init_bn_resnet():
    """init/mod.rs init_bn: running means zero, running vars one."""
    bn = []
    for co in RESNET_CHANNELS:
        bn.append(np.zeros(co, dtype=np.float32))
        bn.append(np.ones(co, dtype=np.float32))
    return bn


def resnet_step(params, bn, gsum, x, y, fmt, enable, hyper, momentum=0.1):
    """runtime/native/step.rs train step on the resnet plan: BN convs run
    the GEMM bias-free, then batchnorm (batch stats + running-average
    fold), then the pre-ReLU skip-add, ReLU, pool, STE quantizer. The
    backward sweep parks residual/branch gradients exactly like the
    interpreter: a residual consumer parks into the skip slot of the
    output it read; a branch successor parks its input gradient into the
    shared input slot and takes the parked branch-output gradient as its
    hand-off. Returns (loss, ce, acc) and updates params/bn/gsum."""
    lr, l1, l2, pen, gnorm = hyper
    L = len(RESNET_GEOMS)
    scale, qmin, qmax = fmt
    b = len(y)
    c = RESNET_KDIMS[-1][1]
    mom = F32(momentum)
    keep = F32(F32(1.0) - mom)

    wq, mask_w, sparsity = [], [], []
    for i in range(L):
        w = params[RESNET_WIRING[i][0]]
        if enable:
            q, mk = quant_ste(w, scale, qmin, qmax)
            zeros = int(np.count_nonzero(q == 0.0))
        else:
            q, mk = w.copy(), np.ones_like(w)
            zeros = int(np.count_nonzero(w == 0.0))
        wq.append(q)
        mask_w.append(mk)
        sparsity.append(F32(zeros) / F32(w.size))

    bn_new = [v.copy() for v in bn]
    acts = [x.reshape(b, -1).astype(np.float32)]
    pre_q, mask_a, cols_of = [], [], []
    xhat_of, k_of = [None] * L, [None] * L
    for i, g in enumerate(RESNET_GEOMS):
        ki, gi, bti, mi, vi, bi = RESNET_WIRING[i]
        x_in = acts[RESNET_SRC[i]]
        if g is None:
            cols_of.append(None)
            z = matmul_seq(x_in, wq[i])
            z = (z + params[bi]).astype(np.float32)
            if i + 1 < L:
                z = np.maximum(z, F32(0.0))
            pre_quant = z
        else:
            cols = im2col(g, x_in)
            cols_of.append(cols)
            z = matmul_seq(cols, wq[i])  # bias-free: BN supplies the shift
            z, xh, kk, mu, var = bn_fwd_train(z, params[gi], params[bti])
            xhat_of[i], k_of[i] = xh, kk
            bn_new[mi] = (
                (keep * bn[mi]).astype(np.float32) + (mom * mu).astype(np.float32)
            ).astype(np.float32)
            bn_new[vi] = (
                (keep * bn[vi]).astype(np.float32) + (mom * var).astype(np.float32)
            ).astype(np.float32)
            if g.residual_from is not None:
                skip = acts[g.residual_from + 1].reshape(z.shape)
                z = (z + skip).astype(np.float32)
            if g.relu:
                z = np.maximum(z, F32(0.0))
            if g.pool > 1:
                pooled = (
                    avgpool_fwd(g, z, b) if g.pool_kind == "avg" else maxpool_fwd(g, z, b)
                )
                pre_quant = pooled
            else:
                pre_quant = z.reshape(b, -1)
        if enable:
            q, mk = quant_ste(pre_quant, scale, qmin, qmax)
        else:
            q, mk = pre_quant.copy(), np.ones_like(pre_quant)
        pre_q.append(z)
        mask_a.append(mk)
        acts.append(q.reshape(b, -1))

    logits = acts[L]
    g = np.zeros((b, c), dtype=np.float32)
    ce_sum = 0.0
    correct = 0
    inv_b = F32(1.0 / b)
    for r in range(b):
        row = logits[r]
        mx = F32(np.max(row))
        se = F32(0.0)
        for j in range(c):
            se = F32(se + F32(np.exp(F32(row[j] - mx))))
        lse = F32(mx + F32(np.log(se)))
        ce_sum += float(F32(lse - row[y[r]]))
        if int(np.argmax(row)) == y[r]:
            correct += 1
        for j in range(c):
            p = F32(np.exp(F32(row[j] - lse)))
            oh = F32(1.0) if j == y[r] else F32(0.0)
            g[r, j] = F32(F32(p - oh) * inv_b)
    ce = F32(ce_sum / b)
    acc = correct / b

    reg = F32(0.0)
    for i in range(L):
        w = params[RESNET_WIRING[i][0]].astype(np.float64)
        s1 = float(np.sum(np.abs(w)))
        s2 = float(np.sum(w * w))
        reg = F32(reg + F32(F32(F32(l1) * F32(s1)) + F32(F32(0.5) * F32(F32(l2) * F32(s2)))))
    wl32 = F32(8.0 / 32.0) if enable else F32(32.0 / 32.0)
    penalty = F32(0.0)
    for i in range(L):
        penalty = F32(penalty + F32(F32(pen) * F32(wl32 * F32(F32(1.0) - sparsity[i]))))
    loss = F32(F32(ce + reg) + penalty)

    skip_g = {}
    for i in range(L - 1, -1, -1):
        geom = RESNET_GEOMS[i]
        ki, gi, bti, mi, vi, bi = RESNET_WIRING[i]
        g = (g.reshape(mask_a[i].shape) * mask_a[i]).astype(np.float32)
        db = None
        dgamma = dbeta = None
        if geom is None:
            if i + 1 < L:
                g = np.where(pre_q[i] > 0.0, g, F32(0.0)).astype(np.float32)
            g_full = g
            db = np.zeros(g_full.shape[1], dtype=np.float32)
            for r in range(g_full.shape[0]):
                db = (db + g_full[r]).astype(np.float32)
            dw = matmul_at_b_seq(acts[RESNET_SRC[i]], g_full)
            if i > 0:
                gp = matmul_a_bt_seq(g_full, wq[i]).reshape(b, -1)
        else:
            if geom.pool > 1:
                if geom.pool_kind == "avg":
                    g_full = avgpool_bwd(geom, g, b)
                else:
                    g_full = maxpool_bwd(geom, pre_q[i], g, b)
            else:
                g_full = g.reshape(-1, geom.co).copy()
            if geom.relu:
                g_full = np.where(pre_q[i] > 0.0, g_full, F32(0.0)).astype(np.float32)
            if geom.residual_from is not None:
                t = geom.residual_from + 1
                flat = g_full.reshape(b, -1)
                if t in skip_g:
                    skip_g[t] = (skip_g[t] + flat).astype(np.float32)
                else:
                    skip_g[t] = flat.copy()
            g_full, dgamma, dbeta = bn_bwd(g_full, xhat_of[i], k_of[i])
            dw = matmul_at_b_seq(cols_of[i], g_full)
            if i > 0:
                gp = matmul_a_bt_seq(g_full, wq[i])
                gp = col2im(geom, gp, b)
        src = RESNET_SRC[i]
        if src == i:
            if i > 0 and i in skip_g:
                gp = (gp + skip_g.pop(i)).astype(np.float32)
        else:
            # branch successor: its input gradient parks on the shared
            # slot; the parked branch-output gradient becomes the hand-off
            if src in skip_g:
                skip_g[src] = (skip_g[src] + gp).astype(np.float32)
            else:
                skip_g[src] = gp.copy()
            gp = skip_g.pop(i)
        dw = (dw * mask_w[i]).astype(np.float32)
        w = params[ki]
        dw = (dw + (F32(l1) * np.sign(w) + F32(l2) * w).astype(np.float32)).astype(
            np.float32
        )
        gn = F32(math.sqrt(float(np.sum(dw.astype(np.float64) ** 2))))
        gsum[i] = (gsum[i] + dw).astype(np.float32)
        denom = F32(gn + F32(1e-12))
        if gnorm:
            params[ki] = (w - F32(lr) * (dw / denom).astype(np.float32)).astype(np.float32)
        else:
            params[ki] = (w - F32(lr) * dw).astype(np.float32)
        if bi is not None:
            params[bi] = (params[bi] - F32(lr) * db).astype(np.float32)
        if gi is not None:
            params[gi] = (params[gi] - F32(lr) * dgamma).astype(np.float32)
            params[bti] = (params[bti] - F32(lr) * dbeta).astype(np.float32)
        if i > 0:
            g = gp
    for i, v in enumerate(bn_new):
        bn[i] = v
    return loss, ce, acc


def resnet_infer_accuracy(params, bn, data, fmt, enable, batch, n_batches):
    """The NativeInfer path: frozen running stats fold into each conv's
    kernel+bias (fold-before-quantize), then the plain quantized forward."""
    L = len(RESNET_GEOMS)
    scale, qmin, qmax = fmt
    wq, biases = [], []
    for i in range(L):
        ki, gi, bti, mi, vi, bi = RESNET_WIRING[i]
        if gi is not None:
            wf, bf = bn_fold(params[ki], params[gi], params[bti], bn[mi], bn[vi])
        else:
            wf, bf = params[ki], params[bi]
        if enable:
            q, _ = quant_ste(wf, scale, qmin, qmax)
        else:
            q = wf
        wq.append(q)
        biases.append(bf)
    accs = []
    for kb in range(n_batches):
        xs, ys = [], []
        for j in range(batch):
            idx = (kb * batch + j) % data.len
            xv, yv = data.fill(idx)
            xs.append(xv)
            ys.append(yv)
        acts = [np.stack(xs).reshape(batch, -1).astype(np.float32)]
        for i, g in enumerate(RESNET_GEOMS):
            h = acts[RESNET_SRC[i]]
            if g is None:
                z = matmul_seq(h, wq[i])
                z = (z + biases[i]).astype(np.float32)
                if i + 1 < L:
                    z = np.maximum(z, F32(0.0))
            else:
                z = matmul_seq(im2col(g, h), wq[i])
                z = (z + biases[i]).astype(np.float32)
                if g.residual_from is not None:
                    z = (z + acts[g.residual_from + 1].reshape(z.shape)).astype(np.float32)
                if g.relu:
                    z = np.maximum(z, F32(0.0))
                if g.pool > 1:
                    z = avgpool_fwd(g, z, batch) if g.pool_kind == "avg" else maxpool_fwd(g, z, batch)
            if enable:
                h, _ = quant_ste(z, scale, qmin, qmax)
            else:
                h = z
            acts.append(h.reshape(batch, -1))
        accs.append(float(np.mean(np.argmax(acts[L], axis=1) == ys)))
    return sum(accs) / len(accs)


def resnet_run(train_size, eval_size, steps, enable=True, report_every=0):
    """The resnet golden/learncheck driver: identical data/batcher/init
    seeding to the mlp/lenet runs (8x8x1 SyntheticVision, batch 16)."""
    data = SyntheticVision(8, 8, 1, 10, train_size, SEED, 0.25)
    evald = SyntheticVision(8, 8, 1, 10, train_size, SEED, 0.25).heldout(
        train_size, eval_size
    )
    params = init_params_resnet(SEED)
    bn = init_bn_resnet()
    gsum = [np.zeros(d, dtype=np.float32) for d in RESNET_KDIMS]
    batcher = Batcher(data, 16, SEED ^ 0xBA7C4)
    ces = []
    for t in range(steps):
        x, y = batcher.next_batch()
        loss, ce, acc = resnet_step(params, bn, gsum, x, y, FMT_8_4, enable, HYPER)
        ces.append(float(ce))
        if report_every and (t + 1) % report_every == 0:
            print(f"  step {t + 1:4d}: ce {ce:.6f} acc {acc:.3f}")
    ev = resnet_infer_accuracy(
        params, bn, evald, FMT_8_4, enable, 16, max(eval_size // 16, 1)
    )
    return ces, ev


def run(train_size, eval_size, steps, enable=True, report_every=0, lenet=False):
    hw = 12 if lenet else 8
    layers = LENET_GEOMS if lenet else None
    dims = LENET_DIMS if lenet else DIMS
    data = SyntheticVision(hw, hw, 1, 10, train_size, SEED, 0.25)
    evald = SyntheticVision(hw, hw, 1, 10, train_size, SEED, 0.25).heldout(
        train_size, eval_size
    )
    params = init_params(dims, SEED)
    gsum = [np.zeros(d, dtype=np.float32) for d in dims]
    batcher = Batcher(data, 16, SEED ^ 0xBA7C4)
    ces = []
    for t in range(steps):
        x, y = batcher.next_batch()
        loss, ce, acc = native_step(params, gsum, x, y, FMT_8_4, enable, HYPER, layers)
        ces.append(float(ce))
        if report_every and (t + 1) % report_every == 0:
            print(f"  step {t + 1:4d}: ce {ce:.6f} acc {acc:.3f}")
    ev = infer_accuracy(
        params, evald, FMT_8_4, enable, 16, max(eval_size // 16, 1), layers
    )
    return ces, ev


def main():
    mode = sys.argv[1] if len(sys.argv) > 1 else "golden"
    if mode in ("golden", "lenet-golden", "resnet-golden"):
        # the golden-test config: epochs=1, train_size=128 -> 8 steps; the
        # first 4 CEs are switch-free by the lookback lower bound
        if mode == "resnet-golden":
            ces, _ = resnet_run(128, 32, 8)
        else:
            ces, _ = run(128, 32, 8, lenet=mode.startswith("lenet"))
        print("first 8 CE values (golden = first 4):")
        for i, ce in enumerate(ces):
            print(f"  step {i}: {ce:.6f}")
        print("golden json snippet:", [round(c, 6) for c in ces[:4]])
    elif mode == "resnet-learncheck":
        # a longer constant-<8,4> resnet run (downsample branch + BN +
        # global avgpool) backing the resnet e2e thresholds
        print("quantized <8,4> resnet, 2 epochs x 256 samples (32 steps):")
        ces, ev = resnet_run(256, 64, 32, report_every=8)
        first = sum(ces[:4]) / 4.0
        last = sum(ces[-4:]) / 4.0
        print(f"  CE {first:.4f} -> {last:.4f}; held-out acc {ev:.4f}")
    elif mode == "lenet-learncheck":
        # a longer constant-<8,4> lenet run backing the conv e2e thresholds
        print("quantized <8,4> lenet, 2 epochs x 256 samples (32 steps):")
        ces, ev = run(256, 64, 32, lenet=True, report_every=8)
        first = sum(ces[:4]) / 4.0
        last = sum(ces[-4:]) / 4.0
        print(f"  CE {first:.4f} -> {last:.4f}; held-out acc {ev:.4f}")
    elif mode == "learncheck":
        # the fast e2e profile at constant <8,4> — a lower bound on AdaPT
        print("quantized <8,4>, 4 epochs x 512 samples (128 steps):")
        ces, ev = run(512, 128, 128, enable=True, report_every=16)
        first = sum(ces[:4]) / 4.0
        last = sum(ces[-4:]) / 4.0
        print(f"  CE {first:.4f} -> {last:.4f}; held-out acc {ev:.4f}")
        print("float32 baseline (enable=0), 2 epochs (64 steps):")
        ces, ev = run(512, 128, 64, enable=False, report_every=16)
        first = sum(ces[:4]) / 4.0
        last = sum(ces[-4:]) / 4.0
        print(f"  CE {first:.4f} -> {last:.4f}; held-out acc {ev:.4f}")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
