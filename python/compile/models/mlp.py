"""3-layer MLP — the quickstart model (and the smallest AOT artifact)."""

from __future__ import annotations

import math

from .. import layers as L


HIDDEN = (256, 128)


def build(input_shape, num_classes):
    from . import ModelDef

    fin = math.prod(input_shape)
    dims = [fin, *HIDDEN, num_classes]

    param_specs, layer_infos = [], []
    for i in range(len(dims) - 1):
        d_in, d_out = dims[i], dims[i + 1]
        param_specs.append(
            L.ParamSpec(f"fc{i}.kernel", (d_in, d_out), "kernel", i, d_in, True)
        )
        param_specs.append(L.ParamSpec(f"fc{i}.bias", (d_out,), "bias", -1, d_in, False))
        layer_infos.append(
            L.LayerInfo(f"fc{i}", "dense", L.dense_madds(d_in, d_out), d_in * d_out, d_in)
        )

    n_dense = len(dims) - 1

    def apply(params, bn_state, x, ctx, train):
        del train
        P = L.ParamCursor(params)
        h = x.reshape(x.shape[0], -1)
        for i in range(n_dense):
            w, b = P.take(), P.take()
            h = L.qdense(ctx, i, h, w, b)
            if i < n_dense - 1:
                h = L.relu(h)
            h = ctx.quant_a(i, h)
        assert P.done()
        return h, bn_state

    return ModelDef("mlp", param_specs, [], layer_infos, apply)
