"""ResNet-20 (CIFAR) — the paper's second tab. 1-4 / fig. 3 & 6 workload.

Standard He et al. CIFAR ResNet: conv16 + 3 stages x 3 basic blocks
(16/32/64 channels) + global avgpool + fc, BatchNorm after every conv,
projection (1x1 conv, "D" layers in the paper's fig. 3) shortcuts at stage
transitions. Conv/dense kernels are quantized; BN params/stats are not.

Within a block with a projection the quantizable-layer order is
(downsample, conv_a, conv_b) so that QuantCtx records per-layer metrics in
index order (quant_a/quant_w calls must be made in ascending layer index).
"""

from __future__ import annotations

from .. import layers as L

STAGES = (16, 32, 64)
BLOCKS_PER_STAGE = 3


def build(input_shape, num_classes):
    from . import ModelDef

    h, w, cin = input_shape
    specs, infos, bns = [], [], []
    li = 0

    def add_conv(name, k, ci, co, hh, ww, stride, kind="conv"):
        nonlocal li
        specs.append(L.ParamSpec(f"{name}.kernel", (k, k, ci, co), "kernel", li, k * k * ci, True))
        madds, (oh, ow) = L.conv_madds(hh, ww, k, ci, co, stride, "SAME")
        infos.append(
            L.LayerInfo(name, kind, madds, k * k * ci * co, k * k * ci, stride=stride, padding="same")
        )
        li += 1
        return oh, ow

    def add_bn(name, c):
        specs.append(L.ParamSpec(f"{name}.gamma", (c,), "gamma", -1, c, False))
        specs.append(L.ParamSpec(f"{name}.beta", (c,), "beta", -1, c, False))
        bns.append(L.BnSpec(f"{name}.mean", (c,)))
        bns.append(L.BnSpec(f"{name}.var", (c,)))

    # stem
    hh, ww = add_conv("conv0", 3, cin, STAGES[0], h, w, 1)
    add_bn("bn0", STAGES[0])

    # blocks: record (has_down, stride, ci, co) to drive apply()
    plan = []
    ci = STAGES[0]
    for si, co in enumerate(STAGES):
        for bi in range(BLOCKS_PER_STAGE):
            stride = 2 if (si > 0 and bi == 0) else 1
            down = stride != 1 or ci != co
            name = f"s{si}b{bi}"
            if down:
                add_conv(f"{name}.down", 1, ci, co, hh, ww, stride, kind="downsample")
                add_bn(f"{name}.bn_down", co)
            oh, ow = add_conv(f"{name}.conv_a", 3, ci, co, hh, ww, stride)
            add_bn(f"{name}.bn_a", co)
            add_conv(f"{name}.conv_b", 3, co, co, oh, ow, 1)
            add_bn(f"{name}.bn_b", co)
            plan.append((down, stride))
            hh, ww, ci = oh, ow, co

    fc_li = li
    specs.append(L.ParamSpec("fc.kernel", (STAGES[-1], num_classes), "kernel", fc_li, STAGES[-1], True))
    specs.append(L.ParamSpec("fc.bias", (num_classes,), "bias", -1, STAGES[-1], False))
    infos.append(
        L.LayerInfo("fc", "dense", L.dense_madds(STAGES[-1], num_classes), STAGES[-1] * num_classes, STAGES[-1])
    )

    def apply(params, bn_state, x, ctx, train):
        P = L.ParamCursor(params)
        bn_out = []
        bn_i = [0]

        def bn(xx, mom=0.1):
            gamma, beta = P.take(), P.take()
            rm, rv = bn_state[bn_i[0]], bn_state[bn_i[0] + 1]
            bn_i[0] += 2
            y, nm, nv = L.batchnorm(xx, gamma, beta, rm, rv, mom, train)
            bn_out.extend([nm, nv])
            return y

        cur = 0
        hx = L.qconv(ctx, cur, x, P.take(), None)
        hx = L.relu(bn(hx))
        hx = ctx.quant_a(cur, hx)
        cur += 1

        for down, stride in plan:
            shortcut = hx
            if down:
                shortcut = L.qconv(ctx, cur, hx, P.take(), None, stride=stride)
                shortcut = bn(shortcut)
                shortcut = ctx.quant_a(cur, shortcut)
                cur += 1
            y = L.qconv(ctx, cur, hx, P.take(), None, stride=stride)
            y = ctx.quant_a(cur, L.relu(bn(y)))
            cur += 1
            y = L.qconv(ctx, cur, y, P.take(), None)
            y = bn(y)
            hx = ctx.quant_a(cur, L.relu(y + shortcut))
            cur += 1

        hx = L.global_avgpool(hx)
        hx = L.qdense(ctx, cur, hx, P.take(), P.take())
        hx = ctx.quant_a(cur, hx)
        assert P.done()
        return hx, bn_out

    return ModelDef("resnet20", specs, bns, infos, apply)
