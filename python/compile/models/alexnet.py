"""AlexNet (CIFAR adaptation) — the paper's tab. 1-4 / fig. 4-5 workload.

The paper trains "AlexNet" on 32x32 CIFAR images without publishing the exact
downscaling; we use the common CIFAR adaptation (5 conv + 3 fc, 3x3 kernels,
three 2x2 maxpools), with classifier widths 1024/512 so the model trains in
reasonable time on the single-core CPU testbed (see DESIGN.md #Substitutions).
8 quantizable layers; ~5.8M parameters for 10 classes.
"""

from __future__ import annotations

from .. import layers as L

CONVS = [
    # (name, cout, pool_after)
    ("conv0", 64, True),
    ("conv1", 192, True),
    ("conv2", 384, False),
    ("conv3", 256, False),
    ("conv4", 256, True),
]
FCS = [1024, 512]


def build(input_shape, num_classes):
    from . import ModelDef

    h, w, cin = input_shape
    specs, infos = [], []

    ci, hh, ww = cin, h, w
    for li, (name, co, pool) in enumerate(CONVS):
        specs.append(L.ParamSpec(f"{name}.kernel", (3, 3, ci, co), "kernel", li, 9 * ci, True))
        specs.append(L.ParamSpec(f"{name}.bias", (co,), "bias", -1, 9 * ci, False))
        madds, (oh, ow) = L.conv_madds(hh, ww, 3, ci, co)
        infos.append(
            L.LayerInfo(
                name, "conv", madds, 9 * ci * co, 9 * ci,
                padding="same", pool=2 if pool else 1,
            )
        )
        hh, ww, ci = oh, ow, co
        if pool:
            hh, ww = hh // 2, ww // 2

    flat = hh * ww * ci
    dims = [flat, *FCS, num_classes]
    for j in range(len(dims) - 1):
        li = len(CONVS) + j
        fi, fo = dims[j], dims[j + 1]
        specs.append(L.ParamSpec(f"fc{j}.kernel", (fi, fo), "kernel", li, fi, True))
        specs.append(L.ParamSpec(f"fc{j}.bias", (fo,), "bias", -1, fi, False))
        infos.append(L.LayerInfo(f"fc{j}", "dense", L.dense_madds(fi, fo), fi * fo, fi))

    n_fc = len(dims) - 1

    def apply(params, bn_state, x, ctx, train):
        del train
        P = L.ParamCursor(params)
        hx = x
        for li, (_, _, pool) in enumerate(CONVS):
            hx = L.qconv(ctx, li, hx, P.take(), P.take())
            hx = L.relu(hx)
            if pool:
                hx = L.maxpool(hx)
            hx = ctx.quant_a(li, hx)
        hx = hx.reshape(hx.shape[0], -1)
        for j in range(n_fc):
            li = len(CONVS) + j
            hx = L.qdense(ctx, li, hx, P.take(), P.take())
            if j < n_fc - 1:
                hx = L.relu(hx)
            hx = ctx.quant_a(li, hx)
        assert P.done()
        return hx, bn_state

    return ModelDef("alexnet", specs, [], infos, apply)
