"""LeNet-5 (CIFAR/MNIST variant) — used in the fig. 2 initializer study.

conv6@5x5(SAME) -> pool -> conv16@5x5(VALID) -> pool -> fc120 -> fc84 -> fc.
ReLU nonlinearities, maxpool (modern variant, as the paper trains with Adam
or ASGD on MNIST/FMNIST).
"""

from __future__ import annotations

from .. import layers as L


def build(input_shape, num_classes):
    from . import ModelDef

    h, w, cin = input_shape
    specs, infos = [], []

    def add_conv(name, li, k, ci, co, pad, hh, ww, stride=1, pool=1):
        specs.append(L.ParamSpec(f"{name}.kernel", (k, k, ci, co), "kernel", li, k * k * ci, True))
        specs.append(L.ParamSpec(f"{name}.bias", (co,), "bias", -1, k * k * ci, False))
        madds, (oh, ow) = L.conv_madds(hh, ww, k, ci, co, stride, pad)
        infos.append(
            L.LayerInfo(
                name, "conv", madds, k * k * ci * co, k * k * ci,
                stride=stride, padding=pad.lower(), pool=pool,
            )
        )
        return oh // pool, ow // pool

    def add_dense(name, li, fi, fo):
        specs.append(L.ParamSpec(f"{name}.kernel", (fi, fo), "kernel", li, fi, True))
        specs.append(L.ParamSpec(f"{name}.bias", (fo,), "bias", -1, fi, False))
        infos.append(L.LayerInfo(name, "dense", L.dense_madds(fi, fo), fi * fo, fi))

    oh, ow = add_conv("conv0", 0, 5, cin, 6, "SAME", h, w, pool=2)
    oh, ow = add_conv("conv1", 1, 5, 6, 16, "VALID", oh, ow, pool=2)
    flat = oh * ow * 16
    add_dense("fc0", 2, flat, 120)
    add_dense("fc1", 3, 120, 84)
    add_dense("fc2", 4, 84, num_classes)

    def apply(params, bn_state, x, ctx, train):
        del train
        P = L.ParamCursor(params)
        hx = L.qconv(ctx, 0, x, P.take(), P.take(), padding="SAME")
        hx = ctx.quant_a(0, L.maxpool(L.relu(hx)))
        hx = L.qconv(ctx, 1, hx, P.take(), P.take(), padding="VALID")
        hx = ctx.quant_a(1, L.maxpool(L.relu(hx)))
        hx = hx.reshape(hx.shape[0], -1)
        hx = ctx.quant_a(2, L.relu(L.qdense(ctx, 2, hx, P.take(), P.take())))
        hx = ctx.quant_a(3, L.relu(L.qdense(ctx, 3, hx, P.take(), P.take())))
        hx = ctx.quant_a(4, L.qdense(ctx, 4, hx, P.take(), P.take()))
        assert P.done()
        return hx, bn_state

    return ModelDef("lenet5", specs, [], infos, apply)
