"""Model registry: name -> build(input_shape, num_classes) -> ModelDef."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List

from ..layers import BnSpec, LayerInfo, ParamSpec


@dataclass
class ModelDef:
    name: str
    param_specs: List[ParamSpec]
    bn_specs: List[BnSpec]  # interleaved (mean, var) per batchnorm
    layer_infos: List[LayerInfo]  # quantizable layers, index order
    apply: Callable  # (params, bn_state, x, ctx, train) -> (logits, bn')

    @property
    def num_layers(self) -> int:
        return len(self.layer_infos)


def build(name: str, input_shape, num_classes: int) -> ModelDef:
    from . import alexnet, lenet, mlp, resnet

    registry = {
        "mlp": mlp.build,
        "lenet5": lenet.build,
        "alexnet": alexnet.build,
        "resnet20": resnet.build,
    }
    if name not in registry:
        raise KeyError(f"unknown model '{name}', have {sorted(registry)}")
    return registry[name](tuple(input_shape), num_classes)
