"""AOT compiler: lower train/infer steps to HLO **text** + a JSON manifest.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--configs mlp-mnist,resnet20-c10] [--batch 32]

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .train_step import make_infer, make_train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _io_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_manifest(cfg: M.Config, model, batch: int):
    L = model.num_layers
    params = [
        {
            "name": s.name,
            "shape": list(s.shape),
            "kind": s.kind,
            "layer": s.layer,
            "fan_in": s.fan_in,
            "quantizable": s.quantizable,
        }
        for s in model.param_specs
    ]
    bn = [{"name": s.name, "shape": list(s.shape)} for s in model.bn_specs]
    layers = [
        {
            "name": li.name,
            "kind": li.kind,
            "madds": li.madds,
            "weight_elems": li.weight_elems,
            "fan_in": li.fan_in,
            # conv geometry keys (dense layers carry the defaults; the
            # native backend's lowerer reads them, old manifests without
            # them parse with the same defaults)
            "stride": li.stride,
            "padding": li.padding,
            "pool": li.pool,
            "pool_kind": li.pool_kind,
            "residual_from": li.residual_from,
        }
        for li in model.layer_infos
    ]
    quant_specs = [s for s in model.param_specs if s.quantizable]

    train_inputs = (
        [_io_entry(s.name, s.shape) for s in model.param_specs]
        + [_io_entry(f"gsum.{s.name}", s.shape) for s in quant_specs]
        + [_io_entry(s.name, s.shape) for s in model.bn_specs]
        + [
            _io_entry("x", (batch, *cfg.input_shape)),
            _io_entry("y", (batch,), "i32"),
            _io_entry("qparams", (2 * L, 5)),
            _io_entry("hyper", (8,)),
        ]
    )
    train_outputs = (
        [_io_entry(s.name, s.shape) for s in model.param_specs]
        + [_io_entry(f"gsum.{s.name}", s.shape) for s in quant_specs]
        + [_io_entry(s.name, s.shape) for s in model.bn_specs]
        + [
            _io_entry("loss", ()),
            _io_entry("ce", ()),
            _io_entry("acc", ()),
            _io_entry("grad_norm", (L,)),
            _io_entry("gsum_norm", (L,)),
            _io_entry("sparsity", (L,)),
            _io_entry("act_absmax", (L,)),
        ]
    )
    infer_inputs = (
        [_io_entry(s.name, s.shape) for s in model.param_specs]
        + [_io_entry(s.name, s.shape) for s in model.bn_specs]
        + [
            _io_entry("x", (batch, *cfg.input_shape)),
            _io_entry("qparams", (2 * L, 5)),
        ]
    )
    infer_outputs = [_io_entry("logits", (batch, cfg.classes))]

    return {
        "name": cfg.name,
        "model": cfg.model,
        "batch": batch,
        "input_shape": list(cfg.input_shape),
        "classes": cfg.classes,
        "num_layers": L,
        "params": params,
        "bn_state": bn,
        "layers": layers,
        "train_inputs": train_inputs,
        "train_outputs": train_outputs,
        "infer_inputs": infer_inputs,
        "infer_outputs": infer_outputs,
    }


def lower_config(cfg: M.Config, batch: int, out_dir: str, verbose: bool = True):
    model = M.build_model(cfg)
    L = model.num_layers

    p_specs = [_f32(s.shape) for s in model.param_specs]
    g_specs = [_f32(s.shape) for s in model.param_specs if s.quantizable]
    b_specs = [_f32(s.shape) for s in model.bn_specs]
    x_spec = _f32((batch, *cfg.input_shape))
    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    qp_spec = _f32((2 * L, 5))
    hy_spec = _f32((8,))

    step = make_train_step(model)
    lowered = jax.jit(step).lower(
        p_specs, g_specs, b_specs, x_spec, y_spec, qp_spec, hy_spec
    )
    train_text = to_hlo_text(lowered)

    infer = make_infer(model)
    lowered_i = jax.jit(infer).lower(p_specs, b_specs, x_spec, qp_spec)
    infer_text = to_hlo_text(lowered_i)

    manifest = build_manifest(cfg, model, batch)
    manifest["train_hlo_sha256"] = hashlib.sha256(train_text.encode()).hexdigest()
    manifest["infer_hlo_sha256"] = hashlib.sha256(infer_text.encode()).hexdigest()

    os.makedirs(out_dir, exist_ok=True)
    base = os.path.join(out_dir, cfg.name)
    with open(f"{base}.train.hlo.txt", "w") as f:
        f.write(train_text)
    with open(f"{base}.infer.hlo.txt", "w") as f:
        f.write(infer_text)
    with open(f"{base}.manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(
            f"[aot] {cfg.name}: train={len(train_text)//1024} KiB "
            f"infer={len(infer_text)//1024} KiB L={L} "
            f"params={sum(int(jnp.prod(jnp.array(s.shape))) for s in model.param_specs)}"
        )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default=",".join(M.CONFIGS))
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args(argv)

    names = [n for n in args.configs.split(",") if n]
    for n in names:
        if n not in M.CONFIGS:
            print(f"unknown config {n!r}; have {sorted(M.CONFIGS)}", file=sys.stderr)
            return 1
    for n in names:
        lower_config(M.CONFIGS[n], args.batch, args.out)
    # stamp so `make artifacts` can no-op on unchanged inputs
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
