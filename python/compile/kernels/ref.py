"""Pure-jnp oracle for the Pallas kernels in ``fixedpoint.py``.

Every kernel has a reference here with identical semantics; pytest asserts
bit-exact (quantize) / allclose (matmul) agreement. This is the CORE
correctness signal for L1.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_sr_ref(x, u, scale, qmin, qmax, enable):
    """Stochastic-rounding fixed-point quantize, reference semantics."""
    q = jnp.floor(x * scale + u)
    q = jnp.clip(q, qmin, qmax)
    return jnp.where(enable > 0.5, q / scale, x)


def quantize_nr_ref(x, scale, qmin, qmax, enable):
    """Nearest-rounding (half-to-even) fixed-point quantize."""
    q = jnp.round(x * scale)
    q = jnp.clip(q, qmin, qmax)
    return jnp.where(enable > 0.5, q / scale, x)


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def fixed_point_grid_ref(x, wl, fl):
    """Project onto the signed <WL, FL> grid with nearest rounding — used by
    property tests to check grid membership of kernel outputs."""
    scale = 2.0**fl
    qmax = 2.0 ** (wl - 1) - 1
    qmin = -(2.0 ** (wl - 1))
    return jnp.clip(jnp.round(x * scale), qmin, qmax) / scale
