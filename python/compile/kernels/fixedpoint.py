"""L1 — Pallas fixed-point quantization kernels.

These kernels implement the numeric core of AdaPT (sec. 2.1 / 3.2 of the
paper): signed fixed-point quantization ``<WL, FL>`` with stochastic or
nearest rounding, simulated in float32 exactly like the paper's QPyTorch
setup (values are constrained to the fixed-point grid ``q * 2^-FL`` but kept
in f32 storage so they can flow through any backend).

All kernels are lowered with ``interpret=True`` so they become plain HLO and
run on the CPU PJRT client (real-TPU Mosaic custom-calls cannot). The tiling
is still expressed through ``BlockSpec`` so the HBM<->VMEM schedule documented
in DESIGN.md #Hardware-Adaptation is explicit.

Quantization parameters (scale = 2^FL, clamp bounds, enable flag) are runtime
*arguments*, never compile-time constants: one compiled artifact serves every
precision level the Rust coordinator selects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per grid step for the 1-D elementwise quantizer. 16 Ki f32 values
# = 64 KiB per operand block; x + u + out = 192 KiB of VMEM per step.
BLOCK_ELEMS = 16384

# Matmul tile sizes (rows of x / cols of w per grid cell). K is kept whole:
# at AdaPT model scale (K <= 4096) an (128, 4096) f32 block is 2 MiB.
MM_BLOCK_M = 128
MM_BLOCK_N = 256

INTERPRET = True


# ---------------------------------------------------------------------------
# elementwise fixed-point quantize
# ---------------------------------------------------------------------------

def _quantize_sr_kernel(x_ref, u_ref, s_ref, lo_ref, hi_ref, en_ref, o_ref):
    """Stochastic-rounding fixed-point quantize of one block.

    q = clamp(floor(x * s + u), lo, hi) / s      with u ~ U[0, 1)

    ``floor(x*s + u)`` realises the paper's SR(x) = floor(x) + [P < frac(x)]:
    the +1 happens with probability frac(x * s).
    """
    x = x_ref[...]
    s = s_ref[0]
    q = jnp.floor(x * s + u_ref[...])
    q = jnp.clip(q, lo_ref[0], hi_ref[0])
    y = q / s
    o_ref[...] = jnp.where(en_ref[0] > 0.5, y, x)


def _quantize_nr_kernel(x_ref, s_ref, lo_ref, hi_ref, en_ref, o_ref):
    """Nearest-rounding (round-half-to-even, XLA default) quantize."""
    x = x_ref[...]
    s = s_ref[0]
    q = jnp.round(x * s)
    q = jnp.clip(q, lo_ref[0], hi_ref[0])
    y = q / s
    o_ref[...] = jnp.where(en_ref[0] > 0.5, y, x)


def _pad_flat(x, block):
    """Flatten to 1-D and zero-pad to a multiple of ``block``."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded = (n + block - 1) // block * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat, n, padded


def _scalar_spec():
    # A (1,)-shaped operand broadcast to every grid step.
    return pl.BlockSpec((1,), lambda i: (0,))


def quantize_sr(x, u, scale, qmin, qmax, enable):
    """Stochastically-rounded fixed-point quantize (simulated in f32).

    Args:
      x: any-shape f32 tensor.
      u: uniform [0,1) noise, same shape as ``x``.
      scale: scalar f32, ``2^FL``.
      qmin/qmax: scalar f32 integer-grid clamp bounds
        (``-2^(WL-1)`` / ``2^(WL-1)-1`` for signed ``<WL, FL>``).
      enable: scalar f32; <= 0.5 bypasses quantization (float32 baseline).

    Returns f32 tensor of ``x.shape`` on the fixed-point grid.
    """
    flat, n, padded = _pad_flat(x, BLOCK_ELEMS)
    uflat, _, _ = _pad_flat(u, BLOCK_ELEMS)
    grid = padded // BLOCK_ELEMS
    out = pl.pallas_call(
        _quantize_sr_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ELEMS,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_ELEMS,), lambda i: (i,)),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((BLOCK_ELEMS,), lambda i: (i,)),
        interpret=INTERPRET,
    )(
        flat,
        uflat,
        jnp.reshape(scale.astype(jnp.float32), (1,)),
        jnp.reshape(qmin.astype(jnp.float32), (1,)),
        jnp.reshape(qmax.astype(jnp.float32), (1,)),
        jnp.reshape(enable.astype(jnp.float32), (1,)),
    )
    return out[:n].reshape(x.shape)


def quantize_nr(x, scale, qmin, qmax, enable):
    """Nearest-rounding fixed-point quantize (deterministic; inference path)."""
    flat, n, padded = _pad_flat(x, BLOCK_ELEMS)
    grid = padded // BLOCK_ELEMS
    out = pl.pallas_call(
        _quantize_nr_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ELEMS,), lambda i: (i,)),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((BLOCK_ELEMS,), lambda i: (i,)),
        interpret=INTERPRET,
    )(
        flat,
        jnp.reshape(scale.astype(jnp.float32), (1,)),
        jnp.reshape(qmin.astype(jnp.float32), (1,)),
        jnp.reshape(qmax.astype(jnp.float32), (1,)),
        jnp.reshape(enable.astype(jnp.float32), (1,)),
    )
    return out[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# straight-through estimator wrappers
# ---------------------------------------------------------------------------
#
# Stochastic rounding is not differentiable; the paper trains "through" the
# quantizer with the standard STE [Bengio et al.]. The backward pass is the
# identity masked to the representable range, i.e. gradients for values that
# were clamped at +-(2^(WL-1))/2^FL are zeroed (clipped STE).


@jax.custom_vjp
def quantize_ste(x, u, scale, qmin, qmax, enable):
    return quantize_sr(x, u, scale, qmin, qmax, enable)


def _ste_fwd(x, u, scale, qmin, qmax, enable):
    y = quantize_sr(x, u, scale, qmin, qmax, enable)
    inside = jnp.logical_and(x * scale >= qmin, x * scale <= qmax)
    mask = jnp.where(enable > 0.5, inside.astype(jnp.float32), 1.0)
    return y, mask


def _ste_bwd(mask, g):
    return (g * mask, None, None, None, None, None)


quantize_ste.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def quantize_nr_ste(x, scale, qmin, qmax, enable):
    return quantize_nr(x, scale, qmin, qmax, enable)


def _nr_ste_fwd(x, scale, qmin, qmax, enable):
    y = quantize_nr(x, scale, qmin, qmax, enable)
    inside = jnp.logical_and(x * scale >= qmin, x * scale <= qmax)
    mask = jnp.where(enable > 0.5, inside.astype(jnp.float32), 1.0)
    return y, mask


def _nr_ste_bwd(mask, g):
    return (g * mask, None, None, None, None)


quantize_nr_ste.defvjp(_nr_ste_fwd, _nr_ste_bwd)


# ---------------------------------------------------------------------------
# blocked matmul (dense-layer hot path)
# ---------------------------------------------------------------------------

def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(v, m):
    return (v + m - 1) // m * m


def _matmul_pallas(x, w):
    """(M,K) @ (K,N) tiled pallas matmul; pads M/N to tile multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    bm = min(MM_BLOCK_M, _ceil_to(m, 8))
    bn = min(MM_BLOCK_N, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def qmatmul(x, w):
    """Pallas-tiled matmul with a hand-written VJP (pallas_call itself is not
    differentiable); both forward and backward run through the same kernel."""
    return _matmul_pallas(x, w)


def _qmm_fwd(x, w):
    return _matmul_pallas(x, w), (x, w)


def _qmm_bwd(res, g):
    x, w = res
    dx = _matmul_pallas(g, w.T)
    dw = _matmul_pallas(x.T, g)
    return dx, dw


qmatmul.defvjp(_qmm_fwd, _qmm_bwd)


# ---------------------------------------------------------------------------
# convenience: WL/FL -> runtime qparams row
# ---------------------------------------------------------------------------

def qparams_row(wl: int, fl: int, enable: float = 1.0):
    """[scale, qmin, qmax, enable, wl] row for a signed <WL, FL> format."""
    scale = float(2**fl)
    qmax = float(2 ** (wl - 1) - 1)
    qmin = float(-(2 ** (wl - 1)))
    return jnp.array([scale, qmin, qmax, enable, float(wl)], dtype=jnp.float32)
