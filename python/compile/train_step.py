"""L2 — the ASGD train step (alg. 1 lines 5-11) and the inference forward.

One jitted step = quantized forward (Pallas kernels inside) + backward via
STE + SGD update of the float32 master copy + gradient-diversity state
accumulation. The Rust coordinator (L3) owns everything between steps:
precision switching (PushDown/PushUp), lookback/resolution/strategy
adaptation, epoch structure, and evaluation.

Loss (sec. 3.4):   L^ = CE + alpha*||W||_1 + beta/2*||W||_2^2 + P
with P = pen * sum_l WL_l/32 * sp_l (stop-gradient; it penalises the
*reported* loss that drives the strategy heuristic).

Gradient normalization (sec. 3.3): kernels' gradients are divided by their
L2 norm before the SGD update when hyper[gnorm] is set; the *raw* gradients
feed the diversity state (eq. 3 uses nabla f, not the normalised update).

hyper layout (f32[8]):
  0: lr   1: l1_decay   2: l2_decay   3: penalty_coef
  4: seed (step counter; folds the PRNG)   5: gnorm_on   6: bn_momentum
  7: reserved
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
from jax import lax

from .layers import QuantCtx

EPS = 1e-12


def _cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def make_train_step(model):
    """Returns step(params, gsum, bn_state, x, y, qparams, hyper) -> tuple.

    Output tuple order (mirrored in the manifest):
      new_params...  new_gsum...  new_bn...  loss  ce  acc
      grad_norm[L]  gsum_norm[L]  sparsity[L]  act_absmax[L]
    """
    L = model.num_layers
    kidx = [i for i, s in enumerate(model.param_specs) if s.quantizable]
    assert len(kidx) == L, (len(kidx), L)

    def step(params: List, gsum: List, bn_state: List, x, y, qparams, hyper):
        lr, l1, l2, pen = hyper[0], hyper[1], hyper[2], hyper[3]
        seed, gnorm_on, bn_mom = hyper[4], hyper[5], hyper[6]
        key = jax.random.PRNGKey(seed.astype(jnp.int32))

        def loss_fn(ps):
            ctx = QuantCtx(qparams, key, stochastic=True, nlayers=L)
            logits, new_bn = model.apply(ps, bn_state, x, ctx, train=True)
            ce = _cross_entropy(logits, y)
            reg = 0.0
            for i in kidx:
                w = ps[i]
                reg = reg + l1 * jnp.sum(jnp.abs(w)) + 0.5 * l2 * jnp.sum(w * w)
            sp = jnp.stack(ctx.sparsity)  # fraction of zeros, per layer
            wl = jnp.stack(ctx.wl)
            # paper's P = WL/32 * sp with sp = % non-zero elements
            penalty = pen * jnp.sum(wl / 32.0 * (1.0 - sp))
            loss = ce + reg + lax.stop_gradient(penalty)
            aux = (logits, new_bn, sp, jnp.stack(ctx.act_absmax), ce)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        logits, new_bn, sparsity, act_absmax, ce = aux

        grad_norms, new_gsum = [], []
        new_params = list(params)
        gi = 0
        for i, g in enumerate(grads):
            if i in set(kidx):
                gn = jnp.sqrt(jnp.sum(g * g))
                grad_norms.append(gn)
                new_gsum.append(gsum[gi] + g)
                gi += 1
                gq = jnp.where(gnorm_on > 0.5, g / (gn + EPS), g)
                new_params[i] = params[i] - lr * gq
            else:
                new_params[i] = params[i] - lr * g
        gsum_norm = [jnp.sqrt(jnp.sum(s * s)) for s in new_gsum]

        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))

        out = (
            *new_params,
            *new_gsum,
            *new_bn,
            loss,
            ce,
            acc,
            jnp.stack(grad_norms),
            jnp.stack(gsum_norm),
            sparsity,
            act_absmax,
        )
        return out

    return step


def make_infer(model):
    """Deterministic quantized forward: (params, bn_state, x, qparams) -> logits.

    Nearest rounding (no noise), BN running statistics — the "deployed on
    ASIC" path of sec. 4.2.2.
    """
    L = model.num_layers

    def infer(params: List, bn_state: List, x, qparams):
        ctx = QuantCtx(qparams, jax.random.PRNGKey(0), stochastic=False, nlayers=L)
        logits, _ = model.apply(params, bn_state, x, ctx, train=False)
        return (logits,)

    return infer
