"""L2 glue: named experiment configs, spec construction, test-time init.

The Rust coordinator initialises parameters itself (TNVS & the fig. 2
initializer zoo live in ``rust/src/init/``); the Python ``init_params`` here
exists for pytest and for numerical parity tests against the Rust
implementation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import models as model_registry
from .models import ModelDef


@dataclass(frozen=True)
class Config:
    name: str
    model: str
    input_shape: Tuple[int, int, int]
    classes: int


CONFIGS: Dict[str, Config] = {
    c.name: c
    for c in [
        Config("mlp-mnist", "mlp", (28, 28, 1), 10),
        Config("lenet-mnist", "lenet5", (28, 28, 1), 10),
        Config("alexnet-c10", "alexnet", (32, 32, 3), 10),
        Config("alexnet-c100", "alexnet", (32, 32, 3), 100),
        Config("resnet20-c10", "resnet20", (32, 32, 3), 10),
        Config("resnet20-c100", "resnet20", (32, 32, 3), 100),
    ]
}


def build_model(cfg: Config) -> ModelDef:
    return model_registry.build(cfg.model, cfg.input_shape, cfg.classes)


def init_params(model: ModelDef, key, s: float = 1.0) -> List[jnp.ndarray]:
    """TNVS init (sec. 3.1): W ~ TruncNormal(0, sqrt(s/fan_in), +-sqrt(3s/fan_in));
    biases/betas zero, gammas one."""
    out = []
    for spec in model.param_specs:
        key, sub = jax.random.split(key)
        if spec.kind == "kernel":
            sigma = math.sqrt(s / spec.fan_in)
            alpha = math.sqrt(3.0 * s / spec.fan_in)
            w = sigma * jax.random.truncated_normal(
                sub, -alpha / sigma, alpha / sigma, spec.shape
            )
            out.append(w.astype(jnp.float32))
        elif spec.kind == "gamma":
            out.append(jnp.ones(spec.shape, jnp.float32))
        else:  # bias, beta
            out.append(jnp.zeros(spec.shape, jnp.float32))
    return out


def init_bn_state(model: ModelDef) -> List[jnp.ndarray]:
    out = []
    for spec in model.bn_specs:
        if spec.name.endswith(".var"):
            out.append(jnp.ones(spec.shape, jnp.float32))
        else:
            out.append(jnp.zeros(spec.shape, jnp.float32))
    return out


def init_gsum(model: ModelDef) -> List[jnp.ndarray]:
    return [
        jnp.zeros(s.shape, jnp.float32)
        for s in model.param_specs
        if s.quantizable
    ]


def default_qparams(model: ModelDef, wl: int = 8, fl: int = 4, enable: float = 1.0):
    """<8,4> everywhere — the paper's initial quantization (sec. 4.1.1)."""
    from .kernels.fixedpoint import qparams_row

    row = qparams_row(wl, fl, enable)
    return jnp.tile(row[None, :], (2 * model.num_layers, 1))


def default_hyper(lr=0.05, l1=1e-5, l2=1e-4, pen=1e-3, seed=0, gnorm=1.0, bn_mom=0.1):
    return jnp.array([lr, l1, l2, pen, float(seed), gnorm, bn_mom, 0.0], jnp.float32)
