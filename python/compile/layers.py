"""L2 building blocks: quantization-aware layers and the QuantCtx.

Models are written as pure functions over a *flat list* of parameter arrays
(the order is recorded in ParamSpec lists and exported to the Rust side via
the manifest). Per-layer quantization parameters arrive at runtime as a
``f32[2L, 5]`` tensor: rows ``0..L`` quantize weights, rows ``L..2L`` quantize
activations (AdaPT sets both from the same <WL, FL>; the MuPPET baseline uses
separate block-floating-point scales for weights and feature maps).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import fixedpoint as fp


# ---------------------------------------------------------------------------
# specs exported through the manifest
# ---------------------------------------------------------------------------


@dataclass
class ParamSpec:
    """One trainable tensor: ordering contract between aot.py and Rust."""

    name: str
    shape: Tuple[int, ...]
    kind: str  # 'kernel' | 'bias' | 'gamma' | 'beta'
    layer: int  # quantizable-layer index, -1 for non-quantized params
    fan_in: int
    quantizable: bool


@dataclass
class LayerInfo:
    """One quantizable layer: input to the analytical performance model and
    (since the conv interpreter) carrier of the geometry keys the native
    backend lowers conv layers from. The geometry fields default to the
    values a dense layer implies, so dense LayerInfos need not set them."""

    name: str
    kind: str  # 'conv' | 'dense' | 'downsample'
    madds: int  # multiply-accumulates per sample (perf model `ops^l`)
    weight_elems: int  # prod(dim in l) for eqs (6), (7)
    fan_in: int
    stride: int = 1  # conv stride (symmetric)
    padding: str = "same"  # 'same' | 'valid' (lower-case in the manifest)
    pool: int = 1  # pool window == stride after the ReLU; 1 = no pool
    pool_kind: str = "max"  # 'max' | 'avg'
    residual_from: int = -1  # skip-add source layer index; -1 = none


@dataclass
class BnSpec:
    name: str
    shape: Tuple[int, ...]


class ParamCursor:
    """Sequential reader over the flat param list (order == ParamSpec order)."""

    def __init__(self, params: List[jnp.ndarray]):
        self._params = params
        self._i = 0

    def take(self) -> jnp.ndarray:
        p = self._params[self._i]
        self._i += 1
        return p

    def done(self) -> bool:
        return self._i == len(self._params)


class QuantCtx:
    """Carries runtime qparams and PRNG state through a model's apply().

    Records, per quantizable layer (in call order == layer index order):
      * sparsity of the quantized weight tensor (fraction of exact zeros)
      * abs-max of the pre-quantization activations (MuPPET scale source)
      * the layer's word length (echoed from qparams, for the penalty term)
    """

    def __init__(self, qparams, key, stochastic: bool, nlayers: int):
        self.qp = qparams  # f32[2L, 5]: scale, qmin, qmax, enable, wl
        self.key = key
        self.stochastic = stochastic
        self.L = nlayers
        self.sparsity: List[jnp.ndarray] = []
        self.act_absmax: List[jnp.ndarray] = []
        self.wl: List[jnp.ndarray] = []

    def _quantize(self, x, row_idx, fold):
        row = self.qp[row_idx]
        if self.stochastic:
            u = jax.random.uniform(jax.random.fold_in(self.key, fold), x.shape)
            return fp.quantize_ste(x, u, row[0], row[1], row[2], row[3])
        return fp.quantize_nr_ste(x, row[0], row[1], row[2], row[3])

    def quant_w(self, li: int, w):
        wq = self._quantize(w, li, 2 * li)
        sp = jnp.mean((lax.stop_gradient(wq) == 0.0).astype(jnp.float32))
        self.sparsity.append(sp)
        self.wl.append(self.qp[li, 4])
        return wq

    def quant_a(self, li: int, a):
        self.act_absmax.append(jnp.max(jnp.abs(lax.stop_gradient(a))))
        return self._quantize(a, self.L + li, 2 * li + 1)


# ---------------------------------------------------------------------------
# layer ops
# ---------------------------------------------------------------------------

DIMNUMS = ("NHWC", "HWIO", "NHWC")


def qconv(ctx: QuantCtx, li: int, x, w, b=None, stride=1, padding="SAME"):
    """Conv with fixed-point-quantized weights (layer index ``li``)."""
    wq = ctx.quant_w(li, w)
    y = lax.conv_general_dilated(
        x, wq, (stride, stride), padding, dimension_numbers=DIMNUMS
    )
    if b is not None:
        y = y + b
    return y


def qdense(ctx: QuantCtx, li: int, x, w, b=None):
    """Dense layer through the Pallas-tiled matmul with quantized weights."""
    wq = ctx.quant_w(li, w)
    y = fp.qmatmul(x, wq)
    if b is not None:
        y = y + b
    return y


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool(x, k=2, s=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))


def batchnorm(x, gamma, beta, rmean, rvar, mom, train: bool, eps=1e-5):
    """BatchNorm over NHWC (per-channel). Returns (y, new_rmean, new_rvar).

    Training uses batch statistics and updates the running stats with
    momentum ``mom``; inference uses the running stats and passes them
    through unchanged. BN params/stats are never quantized (see DESIGN.md).
    """
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_rmean = (1.0 - mom) * rmean + mom * lax.stop_gradient(mean)
        new_rvar = (1.0 - mom) * rvar + mom * lax.stop_gradient(var)
    else:
        mean, var = rmean, rvar
        new_rmean, new_rvar = rmean, rvar
    y = (x - mean) * lax.rsqrt(var + eps) * gamma + beta
    return y, new_rmean, new_rvar


# ---------------------------------------------------------------------------
# MAdds helpers (inputs to the analytical performance model)
# ---------------------------------------------------------------------------


def conv_madds(h, w, k, cin, cout, stride=1, padding="SAME"):
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
    else:  # VALID
        oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    return oh * ow * k * k * cin * cout, (oh, ow)


def dense_madds(fin, fout):
    return fin * fout
