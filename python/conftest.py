"""Make `pytest python/tests/` work from the repo root as well as from
python/ (the tests import the `compile` package relative to this dir)."""
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
