#!/usr/bin/env python3
"""Offline plotting for the figure TSVs emitted by `adapt figure --id N`
and the JSONL run-event logs emitted by `--telemetry` / the supervisor.

Build-time / analysis tooling only (never on the training path). Renders
the paper's figures 3-8 from runs/<profile>/figures/*.tsv into PNGs, or —
with `--events` — the per-layer `<WL>` precision timeline and the CE
trajectory straight from an event log (`telemetry::Event` lines).

Usage:  python python/plot.py [runs/fast/figures] [out_dir]
        python python/plot.py --events runs/events.jsonl [out_dir]
"""

from __future__ import annotations

import json
import pathlib
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

SCHEMA_VERSION = 1


def load_tsv(path: pathlib.Path):
    lines = path.read_text().strip().split("\n")
    header = lines[0].split("\t")
    cols = {h: [] for h in header}
    for line in lines[1:]:
        for h, v in zip(header, line.split("\t")):
            cols[h].append(float(v))
    return header, cols


STYLES = {
    "wordlengths": dict(ylabel="word length (bit)", ylim=(0, 33)),
    "sparsity": dict(ylabel="sparsity (fraction of zero weights)", ylim=(0, 1)),
    "memory": dict(ylabel="memory relative to float32", hline=1.0),
    "cost": dict(ylabel="computational cost relative to float32", hline=1.0),
}


def style_for(name: str):
    for key, st in STYLES.items():
        if key in name:
            return st
    return {}


def plot_tsv(path: pathlib.Path, out_dir: pathlib.Path):
    header, cols = load_tsv(path)
    xs = cols[header[0]]
    fig, ax = plt.subplots(figsize=(8, 4.5))
    for series in header[1:]:
        ax.plot(xs, cols[series], label=series, linewidth=1.1)
    st = style_for(path.stem)
    ax.set_xlabel("training step")
    ax.set_ylabel(st.get("ylabel", "value"))
    if "ylim" in st:
        ax.set_ylim(*st["ylim"])
    if "hline" in st:
        ax.axhline(st["hline"], color="gray", linestyle="--", linewidth=0.8)
    ax.set_title(path.stem.replace("_", " "))
    ncol = 2 if len(header) > 12 else 1
    ax.legend(fontsize=6, ncol=ncol, loc="best")
    fig.tight_layout()
    out = out_dir / f"{path.stem}.png"
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def load_events(path: pathlib.Path):
    """Parse a telemetry JSONL log the way `telemetry::read_log` does:
    complete lines parse independently, garbage/unknown-version lines are
    skipped, an unterminated tail is tolerated."""
    events = []
    skipped = 0
    data = path.read_bytes()
    for raw in data.split(b"\n"):
        if not raw:
            continue
        try:
            ev = json.loads(raw)
        except ValueError:
            skipped += 1
            continue
        if not isinstance(ev, dict) or ev.get("v") != SCHEMA_VERSION:
            skipped += 1
            continue
        events.append(ev)
    if skipped:
        print(f"({skipped} unparseable lines skipped)", file=sys.stderr)
    return events


def replay_trajectory(events):
    """Mirror `telemetry::replay`: fold Step/Switch rows, truncating to the
    carried lengths on rollback/resume so rewound steps drop out."""
    name, mode = "run", ""
    ce, wl_rows, switches = [], [], []
    for ev in events:
        t = ev.get("t")
        if t == "run_start":
            name, mode = ev.get("name", name), ev.get("mode", mode)
        elif t == "step":
            ce.append(ev["ce"])
            wl_rows.append(ev.get("wl", []))
        elif t == "switch":
            switches.append(ev)
        elif t in ("rollback", "resume"):
            keep = ev["steps"]
            del ce[keep:], wl_rows[keep:]
            del switches[ev["switches"]:]
    return name, mode, ce, wl_rows, switches


def plot_events(log_path: pathlib.Path, out_dir: pathlib.Path):
    name, mode, ce, wl_rows, switches = replay_trajectory(load_events(log_path))
    if not ce:
        print(f"no step events in {log_path}", file=sys.stderr)
        return False
    stem = log_path.stem
    xs = list(range(1, len(ce) + 1))

    # per-layer <WL> precision timeline (the fig. 3/4 view, from the log)
    fig, ax = plt.subplots(figsize=(8, 4.5))
    layers = max((len(r) for r in wl_rows), default=0)
    for l in range(layers):
        ax.step(xs, [r[l] if l < len(r) else None for r in wl_rows],
                where="post", label=f"layer {l}", linewidth=1.1)
    ax.set_xlabel("training step")
    ax.set_ylabel("word length (bit)")
    ax.set_ylim(0, 33)
    ax.set_title(f"{name} {mode}: precision timeline ({len(switches)} switches)")
    ax.legend(fontsize=6, ncol=2 if layers > 12 else 1, loc="best")
    fig.tight_layout()
    out = out_dir / f"{stem}_wl_timeline.png"
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")

    # CE trajectory
    fig, ax = plt.subplots(figsize=(8, 4.5))
    ax.plot(xs, ce, linewidth=1.1, label="train CE")
    ax.set_xlabel("training step")
    ax.set_ylabel("cross-entropy")
    ax.set_title(f"{name} {mode}: CE trajectory")
    ax.legend(fontsize=8, loc="best")
    fig.tight_layout()
    out = out_dir / f"{stem}_ce.png"
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")
    return True


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--events":
        if len(sys.argv) < 3:
            print("usage: python python/plot.py --events <events.jsonl> [out_dir]",
                  file=sys.stderr)
            return 2
        log = pathlib.Path(sys.argv[2])
        if not log.exists():
            print(f"no event log at {log}", file=sys.stderr)
            return 1
        out = pathlib.Path(sys.argv[3] if len(sys.argv) > 3 else log.parent)
        out.mkdir(parents=True, exist_ok=True)
        return 0 if plot_events(log, out) else 1
    src = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "runs/fast/figures")
    out = pathlib.Path(sys.argv[2] if len(sys.argv) > 2 else src)
    if not src.exists():
        print(f"no TSVs at {src} — run `adapt figure --id 3..8` first", file=sys.stderr)
        return 1
    out.mkdir(parents=True, exist_ok=True)
    found = False
    for tsv in sorted(src.glob("*.tsv")):
        plot_tsv(tsv, out)
        found = True
    if not found:
        print(f"no .tsv files in {src}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
