#!/usr/bin/env python3
"""Offline plotting for the figure TSVs emitted by `adapt figure --id N`.

Build-time / analysis tooling only (never on the training path). Renders
the paper's figures 3-8 from runs/<profile>/figures/*.tsv into PNGs.

Usage:  python python/plot.py [runs/fast/figures] [out_dir]
"""

from __future__ import annotations

import pathlib
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402


def load_tsv(path: pathlib.Path):
    lines = path.read_text().strip().split("\n")
    header = lines[0].split("\t")
    cols = {h: [] for h in header}
    for line in lines[1:]:
        for h, v in zip(header, line.split("\t")):
            cols[h].append(float(v))
    return header, cols


STYLES = {
    "wordlengths": dict(ylabel="word length (bit)", ylim=(0, 33)),
    "sparsity": dict(ylabel="sparsity (fraction of zero weights)", ylim=(0, 1)),
    "memory": dict(ylabel="memory relative to float32", hline=1.0),
    "cost": dict(ylabel="computational cost relative to float32", hline=1.0),
}


def style_for(name: str):
    for key, st in STYLES.items():
        if key in name:
            return st
    return {}


def plot_tsv(path: pathlib.Path, out_dir: pathlib.Path):
    header, cols = load_tsv(path)
    xs = cols[header[0]]
    fig, ax = plt.subplots(figsize=(8, 4.5))
    for series in header[1:]:
        ax.plot(xs, cols[series], label=series, linewidth=1.1)
    st = style_for(path.stem)
    ax.set_xlabel("training step")
    ax.set_ylabel(st.get("ylabel", "value"))
    if "ylim" in st:
        ax.set_ylim(*st["ylim"])
    if "hline" in st:
        ax.axhline(st["hline"], color="gray", linestyle="--", linewidth=0.8)
    ax.set_title(path.stem.replace("_", " "))
    ncol = 2 if len(header) > 12 else 1
    ax.legend(fontsize=6, ncol=ncol, loc="best")
    fig.tight_layout()
    out = out_dir / f"{path.stem}.png"
    fig.savefig(out, dpi=140)
    plt.close(fig)
    print(f"wrote {out}")


def main() -> int:
    src = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "runs/fast/figures")
    out = pathlib.Path(sys.argv[2] if len(sys.argv) > 2 else src)
    if not src.exists():
        print(f"no TSVs at {src} — run `adapt figure --id 3..8` first", file=sys.stderr)
        return 1
    out.mkdir(parents=True, exist_ok=True)
    found = False
    for tsv in sorted(src.glob("*.tsv")):
        plot_tsv(tsv, out)
        found = True
    if not found:
        print(f"no .tsv files in {src}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
