"""L2 model definitions: shapes, spec consistency, quantization-index order."""

import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.layers import QuantCtx


ALL = sorted(M.CONFIGS)


@pytest.mark.parametrize("name", ALL)
def test_build_and_forward_shapes(name):
    cfg = M.CONFIGS[name]
    model = M.build_model(cfg)
    params = M.init_params(model, jax.random.PRNGKey(0))
    bn = M.init_bn_state(model)
    x = jnp.zeros((4, *cfg.input_shape))
    ctx = QuantCtx(M.default_qparams(model), jax.random.PRNGKey(1), True, model.num_layers)
    logits, new_bn = model.apply(params, bn, x, ctx, train=True)
    assert logits.shape == (4, cfg.classes)
    assert len(new_bn) == len(bn)
    # ctx recorded one entry per quantizable layer, in order
    assert len(ctx.sparsity) == model.num_layers
    assert len(ctx.act_absmax) == model.num_layers
    assert len(ctx.wl) == model.num_layers


@pytest.mark.parametrize("name", ALL)
def test_param_specs_shapes_match_init(name):
    cfg = M.CONFIGS[name]
    model = M.build_model(cfg)
    params = M.init_params(model, jax.random.PRNGKey(0))
    assert len(params) == len(model.param_specs)
    for p, s in zip(params, model.param_specs):
        assert p.shape == tuple(s.shape), s.name


@pytest.mark.parametrize("name", ALL)
def test_quantizable_layers_are_contiguous(name):
    """Kernel param layer indices must be 0..L-1 in spec order — the ordering
    contract the Rust coordinator relies on."""
    model = M.build_model(M.CONFIGS[name])
    idx = [s.layer for s in model.param_specs if s.quantizable]
    assert idx == list(range(model.num_layers))
    assert len(model.layer_infos) == model.num_layers


@pytest.mark.parametrize("name", ALL)
def test_layer_infos_have_positive_costs(name):
    model = M.build_model(M.CONFIGS[name])
    for li in model.layer_infos:
        assert li.madds > 0
        assert li.weight_elems > 0
        assert li.fan_in > 0
        assert li.kind in ("conv", "dense", "downsample")


def test_resnet20_structure():
    model = M.build_model(M.CONFIGS["resnet20-c10"])
    kinds = [li.kind for li in model.layer_infos]
    assert kinds.count("downsample") == 2  # stage 1->2 and 2->3 projections
    assert kinds.count("dense") == 1
    assert kinds.count("conv") == 19  # stem + 18 block convs
    assert model.num_layers == 22
    n = sum(int(jnp.prod(jnp.array(s.shape))) for s in model.param_specs)
    assert 0.25e6 < n < 0.3e6  # ~0.27M params, standard ResNet-20


def test_alexnet_structure():
    model = M.build_model(M.CONFIGS["alexnet-c10"])
    kinds = [li.kind for li in model.layer_infos]
    assert kinds == ["conv"] * 5 + ["dense"] * 3


def test_tnvs_init_statistics():
    """TNVS: sigma = sqrt(s/fan_in), truncation at +-sqrt(3 s / fan_in)."""
    model = M.build_model(M.CONFIGS["mlp-mnist"])
    params = M.init_params(model, jax.random.PRNGKey(0), s=1.0)
    spec = model.param_specs[0]
    w = params[0]
    alpha = (3.0 / spec.fan_in) ** 0.5
    assert float(jnp.max(jnp.abs(w))) <= alpha + 1e-6
    assert abs(float(w.mean())) < 1e-3
    # std of a truncated normal at +-sqrt(3)sigma is ~0.84 sigma... loose check
    sigma = (1.0 / spec.fan_in) ** 0.5
    assert 0.5 * sigma < float(w.std()) < 1.05 * sigma


def test_infer_deterministic():
    cfg = M.CONFIGS["lenet-mnist"]
    model = M.build_model(cfg)
    params = M.init_params(model, jax.random.PRNGKey(0))
    bn = M.init_bn_state(model)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, *cfg.input_shape))
    qp = M.default_qparams(model)
    from compile.train_step import make_infer

    infer = jax.jit(make_infer(model))
    a, = infer(params, bn, x, qp)
    b, = infer(params, bn, x, qp)
    assert jnp.all(a == b)
