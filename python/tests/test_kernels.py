"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

The quantize kernels must agree BIT-EXACTLY with the reference; the matmul
kernel must agree to f32 accumulation tolerance. Hypothesis sweeps shapes
and <WL, FL> formats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fixedpoint as fp
from compile.kernels import ref


def _rand(key, shape, scale=4.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


SHAPES = [(7,), (32,), (16385,), (3, 5), (128, 257), (2, 3, 4, 5)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("wl,fl", [(8, 4), (4, 2), (16, 8), (2, 1), (32, 16)])
def test_quantize_sr_matches_ref(shape, wl, fl):
    x = _rand(0, shape)
    u = jax.random.uniform(jax.random.PRNGKey(1), shape)
    s, lo, hi, en, _ = fp.qparams_row(wl, fl)
    got = fp.quantize_sr(x, u, s, lo, hi, en)
    want = ref.quantize_sr_ref(x, u, s, lo, hi, en)
    assert jnp.all(got == want), f"mismatch at {shape} <{wl},{fl}>"


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("wl,fl", [(8, 4), (6, 3), (12, 6)])
def test_quantize_nr_matches_ref(shape, wl, fl):
    x = _rand(2, shape)
    s, lo, hi, en, _ = fp.qparams_row(wl, fl)
    got = fp.quantize_nr(x, s, lo, hi, en)
    want = ref.quantize_nr_ref(x, s, lo, hi, en)
    assert jnp.all(got == want)


def test_quantize_disabled_is_identity():
    x = _rand(3, (513,))
    u = jax.random.uniform(jax.random.PRNGKey(4), x.shape)
    s, lo, hi, _, _ = fp.qparams_row(8, 4)
    en = jnp.float32(0.0)
    assert jnp.all(fp.quantize_sr(x, u, s, lo, hi, en) == x)
    assert jnp.all(fp.quantize_nr(x, s, lo, hi, en) == x)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    wl=st.integers(2, 24),
    frac=st.integers(0, 23),
    seed=st.integers(0, 2**20),
)
def test_quantize_sr_property(n, wl, frac, seed):
    """Output lies on the <WL, FL> grid and within one ULP of the input
    (when the input is inside the representable range)."""
    fl = min(frac, wl - 1)
    x = _rand(seed, (n,), scale=2.0)
    u = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,))
    s, lo, hi, en, _ = fp.qparams_row(wl, fl)
    y = fp.quantize_sr(x, u, s, lo, hi, en)
    # grid membership: y * 2^FL is integral and clamped
    q = y * s
    assert jnp.all(q == jnp.round(q))
    assert jnp.all(q >= lo) and jnp.all(q <= hi)
    # one-ULP bound for in-range values
    ulp = 1.0 / float(s)
    inside = (x >= float(lo) / float(s)) & (x <= float(hi) / float(s))
    err = jnp.abs(y - x)
    assert jnp.all(jnp.where(inside, err <= ulp + 1e-6, True))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 130),
    n=st.integers(1, 70),
    seed=st.integers(0, 1000),
)
def test_qmatmul_matches_ref(m, k, n, seed):
    a = _rand(seed, (m, k), scale=1.0)
    b = _rand(seed + 1, (k, n), scale=1.0)
    got = fp.qmatmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qmatmul_large_tiled():
    a = _rand(10, (300, 500), scale=1.0)
    b = _rand(11, (500, 300), scale=1.0)
    np.testing.assert_allclose(fp.qmatmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_ste_gradient_identity_inside_range():
    x = jnp.linspace(-0.9, 0.9, 101)  # well inside <8,4> range (+-8)
    u = jnp.full_like(x, 0.5)
    s, lo, hi, en, _ = fp.qparams_row(8, 4)
    g = jax.grad(lambda t: fp.quantize_ste(t, u, s, lo, hi, en).sum())(x)
    assert jnp.all(g == 1.0)


def test_ste_gradient_clipped_outside_range():
    # <4,2>: representable range is [-8/4, 7/4] = [-2, 1.75]
    x = jnp.array([-5.0, -2.5, 0.0, 1.0, 3.0])
    u = jnp.full_like(x, 0.5)
    s, lo, hi, en, _ = fp.qparams_row(4, 2)
    g = jax.grad(lambda t: fp.quantize_ste(t, u, s, lo, hi, en).sum())(x)
    assert list(g) == [0.0, 0.0, 1.0, 1.0, 0.0]


def test_ste_gradient_disabled_is_identity():
    x = jnp.array([-100.0, 100.0])
    u = jnp.full_like(x, 0.5)
    s, lo, hi, _, _ = fp.qparams_row(4, 2)
    g = jax.grad(lambda t: fp.quantize_ste(t, u, s, lo, hi, jnp.float32(0.0)).sum())(x)
    assert jnp.all(g == 1.0)


def test_qmatmul_gradients_match_ref():
    a = _rand(20, (33, 47), scale=1.0)
    b = _rand(21, (47, 29), scale=1.0)
    ga = jax.grad(lambda t: (fp.qmatmul(t, b) ** 2).sum())(a)
    gr = jax.grad(lambda t: (ref.matmul_ref(t, b) ** 2).sum())(a)
    np.testing.assert_allclose(ga, gr, rtol=1e-4, atol=1e-4)
    gb = jax.grad(lambda t: (fp.qmatmul(a, t) ** 2).sum())(b)
    gbr = jax.grad(lambda t: (ref.matmul_ref(a, t) ** 2).sum())(b)
    np.testing.assert_allclose(gb, gbr, rtol=1e-4, atol=1e-4)


def test_stochastic_rounding_is_unbiased():
    """E[SR(x)] = x: the statistical property the paper's convergence rests on."""
    x = jnp.full((20000,), 0.3)  # 0.3 * 16 = 4.8, between grid points 4 and 5
    s, lo, hi, en, _ = fp.qparams_row(8, 4)
    u = jax.random.uniform(jax.random.PRNGKey(7), x.shape)
    y = fp.quantize_sr(x, u, s, lo, hi, en)
    assert abs(float(y.mean()) - 0.3) < 2e-3
    # only the two adjacent grid points appear
    vals = set(np.unique(np.asarray(y)).tolist())
    assert vals <= {4.0 / 16.0, 5.0 / 16.0}


def test_qparams_row_values():
    row = fp.qparams_row(8, 4)
    assert list(np.asarray(row)) == [16.0, -128.0, 127.0, 1.0, 8.0]
