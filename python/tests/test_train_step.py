"""ASGD train-step semantics: learning, metrics, master-copy contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.train_step import make_train_step, make_infer


def _setup(name="mlp-mnist", batch=16, seed=0):
    cfg = M.CONFIGS[name]
    model = M.build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(model, key)
    bn = M.init_bn_state(model)
    gsum = M.init_gsum(model)
    qp = M.default_qparams(model)
    # easy separable task: class = sign pattern of the first pixels
    kx, ky = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(kx, (batch, *cfg.input_shape))
    y = jax.random.randint(ky, (batch,), 0, cfg.classes)
    return cfg, model, params, bn, gsum, qp, x, y


def _unpack(model, bn, out):
    P, L, B = len(model.param_specs), model.num_layers, len(bn)
    new_params = list(out[:P])
    new_gsum = list(out[P : P + L])
    new_bn = list(out[P + L : P + L + B])
    loss, ce, acc = out[P + L + B], out[P + L + B + 1], out[P + L + B + 2]
    gn, gs, sp, am = out[P + L + B + 3 :]
    return new_params, new_gsum, new_bn, loss, ce, acc, gn, gs, sp, am


def test_memorizes_small_batch():
    """Overfit one batch: CE must fall substantially under <8,4> quantization."""
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    hyper0 = np.asarray(M.default_hyper(lr=0.1, l1=0.0, l2=0.0, gnorm=1.0))
    first_ce = None
    for i in range(60):
        hy = jnp.asarray(hyper0).at[4].set(float(i))
        out = step(params, gsum, bn, x, y, qp, hy)
        params, gsum, bn, loss, ce, acc, *_ = _unpack(model, bn, out)
        if first_ce is None:
            first_ce = float(ce)
    assert float(ce) < 0.5 * first_ce, (first_ce, float(ce))
    assert float(acc) > 0.8


def test_zero_lr_keeps_master_weights():
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    hy = M.default_hyper(lr=0.0, l1=0.0, l2=0.0)
    out = step(params, gsum, bn, x, y, qp, hy)
    new_params, *_ = _unpack(model, bn, out)
    for a, b in zip(params, new_params):
        assert jnp.all(a == b)


def test_metrics_shapes_and_ranges():
    cfg, model, params, bn, gsum, qp, x, y = _setup("lenet-mnist")
    step = jax.jit(make_train_step(model))
    out = step(params, gsum, bn, x, y, qp, M.default_hyper())
    _, new_gsum, _, loss, ce, acc, gn, gs, sp, am = _unpack(model, bn, out)
    L = model.num_layers
    assert gn.shape == gs.shape == sp.shape == am.shape == (L,)
    assert 0.0 <= float(acc) <= 1.0
    assert float(ce) > 0 and jnp.isfinite(loss)
    assert jnp.all(sp >= 0) and jnp.all(sp <= 1)
    assert jnp.all(am >= 0)
    assert jnp.all(jnp.isfinite(gn)) and jnp.all(gn >= 0)
    # gsum accumulated exactly once -> gsum_norm == grad_norm on first step
    np.testing.assert_allclose(np.asarray(gn), np.asarray(gs), rtol=1e-5)


def test_gsum_accumulates():
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    hy = M.default_hyper(lr=0.0)
    out1 = step(params, gsum, bn, x, y, qp, hy)
    _, gsum1, *_ = _unpack(model, bn, out1)
    out2 = step(params, gsum1, bn, x, y, qp, hy)
    _, gsum2, *_ = _unpack(model, bn, out2)
    # lr=0, same seed -> identical gradients; gsum2 = 2 * gsum1
    for a, b in zip(gsum1, gsum2):
        np.testing.assert_allclose(np.asarray(b), 2 * np.asarray(a), rtol=1e-4, atol=1e-7)


def test_disabled_quantization_is_float32_baseline():
    """enable=0 rows turn the step into plain float32 SGD (the paper's
    baseline) — quantized sparsity metrics then reflect raw zero counts."""
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    qp_off = M.default_qparams(model, enable=0.0)
    step = jax.jit(make_train_step(model))
    hy = M.default_hyper(l1=0.0, l2=0.0, gnorm=0.0)
    out = step(params, gsum, bn, x, y, qp_off, hy)
    new_params, *_ = _unpack(model, bn, out)

    # reference: pure-jnp forward/backward without any quantization
    def ref_loss(ps):
        h = x.reshape(x.shape[0], -1)
        for i in range(0, 6, 2):
            h = h @ ps[i] + ps[i + 1]
            if i < 4:
                h = jnp.maximum(h, 0)
        logp = jax.nn.log_softmax(h)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    g = jax.grad(ref_loss)(params)
    lr = 0.05
    for i, (p, gg) in enumerate(zip(params, g)):
        np.testing.assert_allclose(
            np.asarray(new_params[i]), np.asarray(p - lr * gg), rtol=2e-3, atol=1e-6
        )


def test_l2_regularization_shrinks_weights():
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    hy_reg = M.default_hyper(lr=0.1, l1=0.0, l2=1.0, gnorm=0.0)
    hy_off = M.default_hyper(lr=0.1, l1=0.0, l2=0.0, gnorm=0.0)
    out_r = step(params, gsum, bn, x, y, qp, hy_reg)
    out_o = step(params, gsum, bn, x, y, qp, hy_off)
    w_r = out_r[0]
    w_o = out_o[0]
    assert float(jnp.sum(w_r**2)) < float(jnp.sum(w_o**2))


def test_l1_regularization_induces_sparsity():
    """Sustained L1 pressure + quantization snap-to-zero => rising sparsity."""
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    sp0 = None
    for i in range(40):
        hy = M.default_hyper(lr=0.05, l1=2e-3, l2=0.0, seed=i, gnorm=0.0)
        out = step(params, gsum, bn, x, y, qp, hy)
        params, gsum, bn, loss, ce, acc, gn, gs, sp, am = _unpack(model, bn, out)
        if sp0 is None:
            sp0 = float(sp.mean())
    assert float(sp.mean()) > sp0


def test_gradient_normalization_bounds_update():
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    out = step(params, gsum, bn, x, y, qp, M.default_hyper(lr=1.0, l1=0, l2=0, gnorm=1.0))
    new_params, *_ = _unpack(model, bn, out)
    # normalized kernel update has L2 norm == lr
    kidx = [i for i, s in enumerate(model.param_specs) if s.quantizable]
    for i in kidx:
        d = new_params[i] - params[i]
        np.testing.assert_allclose(float(jnp.sqrt((d**2).sum())), 1.0, rtol=1e-3)


def test_nan_inputs_do_not_crash():
    """Failure injection: a NaN batch must produce a NaN loss, not an error;
    the Rust coordinator detects and skips such steps."""
    cfg, model, params, bn, gsum, qp, x, y = _setup()
    step = jax.jit(make_train_step(model))
    x_bad = x.at[0, 0, 0, 0].set(jnp.nan)
    out = step(params, gsum, bn, x_bad, y, qp, M.default_hyper())
    loss = out[len(model.param_specs) + model.num_layers + len(bn)]
    assert bool(jnp.isnan(loss))


def test_bn_state_updates_in_training():
    cfg, model, params, bn, gsum, qp, x, y = _setup("resnet20-c10", batch=4)
    step = jax.jit(make_train_step(model))
    out = step(params, gsum, bn, x, y, qp, M.default_hyper())
    _, _, new_bn, *_ = _unpack(model, bn, out)
    changed = sum(
        0 if bool(jnp.all(a == b)) else 1 for a, b in zip(bn, new_bn)
    )
    assert changed > 0
