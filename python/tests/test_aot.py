"""AOT path: HLO text lowering + manifest consistency (the L2<->L3 contract)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def mlp_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.lower_config(M.CONFIGS["mlp-mnist"], batch=8, out_dir=str(out), verbose=False)
    return out


def test_hlo_text_parses_as_hlo_module(mlp_artifacts):
    text = (mlp_artifacts / "mlp-mnist.train.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_matches_entry_layout(mlp_artifacts):
    """Every manifest input appears in the HLO entry layout, in order."""
    man = json.loads((mlp_artifacts / "mlp-mnist.manifest.json").read_text())
    text = (mlp_artifacts / "mlp-mnist.train.hlo.txt").read_text()
    header = text.split("->")[0]
    for e in man["train_inputs"]:
        dt = {"f32": "f32", "i32": "s32"}[e["dtype"]]
        dims = ",".join(str(d) for d in e["shape"])
        assert f"{dt}[{dims}]" in header, e


def test_manifest_counts(mlp_artifacts):
    man = json.loads((mlp_artifacts / "mlp-mnist.manifest.json").read_text())
    L = man["num_layers"]
    P = len(man["params"])
    B = len(man["bn_state"])
    assert len(man["layers"]) == L
    assert len(man["train_inputs"]) == P + L + B + 4
    assert len(man["train_outputs"]) == P + L + B + 7
    assert len(man["infer_inputs"]) == P + B + 2
    assert man["train_inputs"][-2]["shape"] == [2 * L, 5]


def test_train_output_order_matches_step(mlp_artifacts):
    """Run the jitted step and compare per-position shapes with the manifest."""
    man = json.loads((mlp_artifacts / "mlp-mnist.manifest.json").read_text())
    cfg = M.CONFIGS["mlp-mnist"]
    model = M.build_model(cfg)
    from compile.train_step import make_train_step

    params = M.init_params(model, jax.random.PRNGKey(0))
    out = jax.jit(make_train_step(model))(
        params,
        M.init_gsum(model),
        M.init_bn_state(model),
        jnp.zeros((8, *cfg.input_shape)),
        jnp.zeros((8,), jnp.int32),
        M.default_qparams(model),
        M.default_hyper(),
    )
    assert len(out) == len(man["train_outputs"])
    for got, want in zip(out, man["train_outputs"]):
        assert list(got.shape) == want["shape"], want["name"]


def test_all_configs_known():
    for name in ["mlp-mnist", "lenet-mnist", "alexnet-c10", "alexnet-c100",
                 "resnet20-c10", "resnet20-c100"]:
        assert name in M.CONFIGS
